//! AOT plan cache: compile once, serve forever.
//!
//! Production serving runs a small set of precompiled batch-size
//! *buckets* per model (static-shape accelerators cannot batch
//! dynamically), so the cache key is everything that determines a
//! compiled artifact: `(model, batch, AccelConfig, decision)`. Each
//! entry memoizes the optimized `(Program, MemoryPlan)` from the pass
//! pipeline — joint beam search (`opt`) or staged-greedy tiling — plus
//! the unified cost model's prediction for it.
//!
//! **Service-time contract:** the artifact's `service_seconds` is
//! `cost::evaluate(..).pipelined_seconds`, and compilation re-replays
//! the plan through `accel::simulate_pipelined` and insists the two
//! agree bit-exactly (the repo-wide calibration invariant). The
//! serving layer can therefore treat the cost model's numbers as the
//! ground-truth service model without re-simulating per request.

use crate::accel::{simulate_pipelined, AccelConfig};
use crate::alloc::MemoryPlan;
use crate::cost::{evaluate, CostBreakdown, DecisionVector, ShardedCost};
use crate::ir::Program;
use crate::passes::{AllocStage, OptStage, PassManager, TileStage};
use crate::shard::{self, ShardOpts};
use crate::util::error::Result;
use crate::util::json::Json;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

/// Everything that determines a compiled serving artifact.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub model: String,
    pub batch: i64,
    /// Accelerator fingerprint: every geometry/bandwidth field that
    /// changes compilation (`AccelConfig` itself is not `Eq`/`Hash`).
    pub accel: String,
    /// Requested decision configuration: `"joint"` for the beam
    /// search (the winner is recorded per-artifact), otherwise the
    /// staged-greedy baseline decision vector.
    pub decision: String,
}

impl PlanKey {
    pub fn describe(&self) -> String {
        format!(
            "{}@b{} on {} [{}]",
            self.model, self.batch, self.accel, self.decision
        )
    }
}

fn accel_fingerprint(cfg: &AccelConfig) -> String {
    format!(
        "{}:{}x{}B:pe{}x{}:v{}:clk{:e}:dram{:e}:copy{:e}:c{}:ic{:e}",
        cfg.name,
        cfg.banks,
        cfg.bank_bytes,
        cfg.pe_rows,
        cfg.pe_cols,
        cfg.vector_lanes,
        cfg.clock_hz,
        cfg.dram_bps,
        cfg.onchip_copy_bps,
        cfg.num_cores,
        cfg.intercore_bps
    )
}

/// One compiled serving artifact: the optimized program and plan for a
/// single `(model, batch)` point, with the cost model's prediction for
/// it and the pipelined service time the planned backend replays.
#[derive(Clone, Debug)]
pub struct PlannedArtifact {
    pub key: PlanKey,
    pub program: Program,
    pub plan: MemoryPlan,
    /// Unified cost-model prediction for `(program, plan)`.
    pub cost: CostBreakdown,
    /// Seconds of one batch execution under the double-buffered
    /// pipeline replay. Equal to `cost.pipelined_seconds` — verified
    /// against `simulate_pipelined` at compile time.
    pub service_seconds: f64,
    /// What `simulate_pipelined` actually measured at compile time:
    /// seconds of one execution. Stored separately from the
    /// prediction so the serving drift auditor compares two
    /// independently produced numbers (they are `ensure!`d equal here,
    /// but a future backend that stops replaying the plan would
    /// diverge — and the audit would show it).
    pub replayed_seconds: f64,
    /// What `simulate_pipelined` actually measured: off-chip bytes of
    /// one execution.
    pub replayed_offchip_bytes: i64,
    /// The decision vector the artifact was realized with (the joint
    /// search's winner, or the staged-greedy baseline).
    pub decision: String,
    pub batch: i64,
    /// Flattened per-request input length (batch dim divided out).
    pub in_len: usize,
    /// Flattened per-request output length.
    pub out_len: usize,
    pub compile_seconds: f64,
    /// Multi-core pipeline sharding of the same `(model, batch)` point
    /// (compiled when `accel.num_cores > 1`): the winning cut vector
    /// with its per-stage plans and the combined multi-core cost,
    /// verified against the multi-engine replay at compile time.
    pub sharded: Option<ShardedPlan>,
}

/// The sharded serving artifact a multi-core backend places: per-stage
/// plans plus the pipeline service model.
#[derive(Clone, Debug)]
pub struct ShardedPlan {
    /// Cut node indices (empty = the search kept one stage).
    pub cuts: Vec<usize>,
    pub stages: Vec<Arc<crate::shard::StageArtifact>>,
    /// Fabric bytes per stage hand-off (last entry 0).
    pub transfer_bytes: Vec<i64>,
    /// Combined multi-core prediction — `bits_eq`-verified against
    /// [`crate::shard::replay_sharded`] at compile time.
    pub cost: ShardedCost,
    /// The widened decision vector (cuts + per-stage decisions).
    pub decision: String,
}

impl ShardedPlan {
    /// Steady-state seconds between batch completions once the
    /// pipeline is full — the sharded service model's throughput term.
    pub fn interval_seconds(&self) -> f64 {
        self.cost.interval_seconds
    }

    /// One batch end-to-end through all stages (fill latency) — the
    /// sharded service model's latency term.
    pub fn latency_seconds(&self) -> f64 {
        self.cost.latency_seconds
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cuts", Json::Arr(self.cuts.iter().map(|&c| Json::Int(c as i64)).collect())),
            ("stages", Json::Int(self.stages.len() as i64)),
            ("decision", Json::Str(self.decision.clone())),
            ("cost", self.cost.to_json()),
        ])
    }
}

impl PlannedArtifact {
    /// Predicted off-chip DRAM bytes amortized per request at full
    /// occupancy of this bucket.
    pub fn bytes_per_request(&self) -> f64 {
        self.cost.offchip_total() as f64 / self.batch as f64
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("model", Json::Str(self.key.model.clone())),
            ("batch", Json::Int(self.batch)),
            ("accel", Json::Str(self.key.accel.clone())),
            ("requested_decision", Json::Str(self.key.decision.clone())),
            ("decision", Json::Str(self.decision.clone())),
            ("offchip_bytes", Json::Int(self.cost.offchip_total())),
            ("bytes_per_request", Json::Num(self.bytes_per_request())),
            ("service_seconds", Json::Num(self.service_seconds)),
            ("peak_scratchpad", Json::Int(self.cost.peak_scratchpad)),
            ("in_len", Json::Int(self.in_len as i64)),
            ("out_len", Json::Int(self.out_len as i64)),
            ("compile_seconds", Json::Num(self.compile_seconds)),
        ];
        if let Some(s) = &self.sharded {
            fields.push(("sharded", s.to_json()));
        }
        Json::obj(fields)
    }
}

/// How the cache compiles: which chip, and joint search vs staged
/// greedy.
#[derive(Clone, Debug)]
pub struct PlanCacheConfig {
    pub accel: AccelConfig,
    /// `true`: whole-model joint beam search (`opt` stage); `false`:
    /// staged-greedy tiling (`tile` stage). Both end in the alloc
    /// stage so every artifact carries a `MemoryPlan`.
    pub joint: bool,
    /// Inter-pass IR verification while compiling (slower; on for
    /// tests, typically off for bulk bucket compilation).
    pub verify: bool,
    /// LRU capacity in buckets (0 = unbounded). When a compile would
    /// grow the cache past this, the least-recently-used bucket is
    /// evicted; evictions are counted and surfaced by the coordinator
    /// as `polymem_plan_cache_evictions_total`.
    pub max_entries: usize,
}

/// Memoizing AOT compiler for one model's batch-size buckets.
pub struct PlanCache {
    model: String,
    cfg: PlanCacheConfig,
    entries: HashMap<i64, Arc<PlannedArtifact>>,
    /// Bucket keys, least-recently-used first.
    recency: Vec<i64>,
    hits: usize,
    misses: usize,
    evictions: u64,
}

impl PlanCache {
    pub fn new(model: impl Into<String>, cfg: PlanCacheConfig) -> PlanCache {
        PlanCache {
            model: model.into(),
            cfg,
            entries: HashMap::new(),
            recency: Vec::new(),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// The cache key a given batch size resolves to.
    pub fn key(&self, batch: i64) -> PlanKey {
        PlanKey {
            model: self.model.clone(),
            batch,
            accel: accel_fingerprint(&self.cfg.accel),
            decision: if self.cfg.joint {
                "joint".to_string()
            } else {
                DecisionVector::baseline().describe()
            },
        }
    }

    pub fn hits(&self) -> usize {
        self.hits
    }

    pub fn misses(&self) -> usize {
        self.misses
    }

    /// Buckets evicted by the LRU cap since construction.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    pub fn contains(&self, batch: i64) -> bool {
        self.entries.contains_key(&batch)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Fetch the artifact for `batch`, compiling and memoizing it on
    /// first use.
    pub fn get_or_compile(&mut self, batch: i64) -> Result<Arc<PlannedArtifact>> {
        if let Some(a) = self.entries.get(&batch) {
            self.hits += 1;
            let a = a.clone();
            self.touch(batch);
            return Ok(a);
        }
        let art = Arc::new(self.compile(batch)?);
        self.misses += 1;
        self.entries.insert(batch, art.clone());
        self.recency.push(batch);
        if self.cfg.max_entries > 0 {
            while self.entries.len() > self.cfg.max_entries {
                let victim = self.recency.remove(0);
                self.entries.remove(&victim);
                self.evictions += 1;
            }
        }
        Ok(art)
    }

    /// Mark `batch` most-recently-used.
    fn touch(&mut self, batch: i64) {
        if let Some(pos) = self.recency.iter().position(|&b| b == batch) {
            let b = self.recency.remove(pos);
            self.recency.push(b);
        }
    }

    /// Compile (or fetch) every bucket, returned in the given order —
    /// the artifact set a `PlannedBackend` serves.
    pub fn compile_buckets(&mut self, buckets: &[i64]) -> Result<Vec<Arc<PlannedArtifact>>> {
        buckets.iter().map(|&b| self.get_or_compile(b)).collect()
    }

    fn compile(&self, batch: i64) -> Result<PlannedArtifact> {
        crate::ensure!(batch >= 1, "bucket batch must be >= 1, got {batch}");
        let t0 = Instant::now();
        let key = self.key(batch);
        let g = crate::models::by_name(&self.model, batch).ok_or_else(|| {
            crate::format_err!("plan cache: unknown model '{}'", self.model)
        })?;
        let total_in: i64 = g.inputs().iter().map(|&id| g.tensor(id).numel()).sum();
        let total_out: i64 = g.outputs().iter().map(|&id| g.tensor(id).numel()).sum();
        crate::ensure!(
            total_in % batch == 0 && total_out % batch == 0,
            "model '{}' does not scale with batch {batch} (in {total_in}, out {total_out})",
            self.model
        );
        let accel = self.cfg.accel.clone();
        let pm = PassManager {
            opt: self.cfg.joint.then(|| OptStage::for_accel(accel.clone())),
            tile: (!self.cfg.joint).then(|| TileStage::for_accel(accel.clone())),
            alloc: Some(AllocStage::for_accel(accel.clone())),
            verify: self.cfg.verify,
            ..PassManager::default()
        };
        let rep = pm
            .run(g)
            .map_err(|e| crate::format_err!("compiling {}: {e}", key.describe()))?;
        let decision = rep
            .opt
            .as_ref()
            .map(|s| s.decision.clone())
            .unwrap_or_else(|| DecisionVector::baseline().describe());
        let program = rep.program;
        let plan = rep.plan.expect("alloc stage always configured");
        let cost = evaluate(&program, &plan, &accel);
        // the service-time contract: the pipelined replay must agree
        // with the prediction the serving layer hands out
        let sim = simulate_pipelined(&program, &plan, &accel, None)
            .map_err(|e| crate::format_err!("replaying {}: {e}", key.describe()))?;
        crate::ensure!(
            sim.seconds == cost.pipelined_seconds
                && sim.offchip_total() == cost.offchip_total(),
            "calibration broken for {}: simulated {}s/{}B vs predicted {}s/{}B",
            key.describe(),
            sim.seconds,
            sim.offchip_total(),
            cost.pipelined_seconds,
            cost.offchip_total()
        );
        // multi-core chips also get the cut-axis search: the winning
        // sharding rides alongside the single-pipeline artifact, held
        // to the same contract against the multi-engine replay
        let sharded = if accel.num_cores > 1 {
            let sg = crate::models::by_name(&self.model, batch).expect("model resolved above");
            let opts =
                ShardOpts { joint: self.cfg.joint, verify: self.cfg.verify, ..ShardOpts::default() };
            let outcome = shard::search_sharded(&sg, &accel, &opts)
                .map_err(|e| crate::format_err!("sharding {}: {e}", key.describe()))?;
            let replay = shard::replay_sharded(&outcome.stages, &outcome.transfer_bytes, &accel)
                .map_err(|e| crate::format_err!("sharded replay {}: {e}", key.describe()))?;
            crate::ensure!(
                outcome.cost.bits_eq(&replay),
                "sharded calibration broken for {}: predicted {}s vs replayed {}s",
                key.describe(),
                outcome.cost.interval_seconds,
                replay.interval_seconds
            );
            Some(ShardedPlan {
                cuts: outcome.cuts.clone(),
                decision: outcome.describe(),
                stages: outcome.stages,
                transfer_bytes: outcome.transfer_bytes,
                cost: outcome.cost,
            })
        } else {
            None
        };
        Ok(PlannedArtifact {
            key,
            program,
            plan,
            service_seconds: cost.pipelined_seconds,
            replayed_seconds: sim.seconds,
            replayed_offchip_bytes: sim.offchip_total(),
            cost,
            decision,
            batch,
            in_len: (total_in / batch) as usize,
            out_len: (total_out / batch) as usize,
            compile_seconds: t0.elapsed().as_secs_f64(),
            sharded,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_model_is_an_error() {
        let mut c = PlanCache::new(
            "no-such-model",
            PlanCacheConfig {
                accel: AccelConfig::tiny(64 * 1024),
                joint: false,
                verify: true,
                max_entries: 0,
            },
        );
        assert!(c.get_or_compile(1).is_err());
        assert_eq!(c.misses(), 0);
        assert!(c.is_empty());
    }

    #[test]
    fn keys_distinguish_batch_accel_and_mode() {
        let mk = |joint, accel| {
            PlanCache::new("mlp", PlanCacheConfig { accel, joint, verify: true, max_entries: 0 })
        };
        let a = mk(false, AccelConfig::tiny(64 * 1024));
        let b = mk(true, AccelConfig::tiny(64 * 1024));
        let c = mk(false, AccelConfig::tiny(128 * 1024));
        assert_ne!(a.key(1), a.key(2));
        assert_ne!(a.key(1), b.key(1));
        assert_ne!(a.key(1), c.key(1));
        assert_eq!(a.key(4), a.key(4));
    }

    #[test]
    fn keys_distinguish_core_count() {
        let mk = |accel| {
            PlanCache::new("mlp", PlanCacheConfig { accel, joint: false, verify: true, max_entries: 0 })
        };
        let one = mk(AccelConfig::tiny(64 * 1024));
        let two = mk(AccelConfig::tiny(64 * 1024).with_cores(2));
        assert_ne!(one.key(1), two.key(1));
    }

    #[test]
    fn lru_cap_evicts_least_recently_used() {
        let mut c = PlanCache::new(
            "mlp",
            PlanCacheConfig {
                accel: AccelConfig::tiny(64 * 1024),
                joint: false,
                verify: true,
                max_entries: 2,
            },
        );
        c.get_or_compile(1).unwrap();
        c.get_or_compile(2).unwrap();
        c.get_or_compile(1).unwrap(); // refresh 1: the LRU victim is now 2
        c.get_or_compile(4).unwrap(); // cap+1-th bucket
        assert_eq!(c.len(), 2);
        assert_eq!(c.evictions(), 1);
        assert!(c.contains(1) && c.contains(4) && !c.contains(2));
        // recompiling the victim is a fresh miss and evicts the new LRU
        c.get_or_compile(2).unwrap();
        assert_eq!(c.misses(), 4);
        assert_eq!(c.hits(), 1);
        assert_eq!(c.evictions(), 2);
        assert!(!c.contains(1) && c.contains(2) && c.contains(4));
    }

    #[test]
    fn multicore_cache_attaches_verified_sharded_plan() {
        let mut c = PlanCache::new(
            "mlp",
            PlanCacheConfig {
                accel: AccelConfig::tiny(8 * 1024).with_cores(2),
                joint: false,
                verify: true,
                max_entries: 0,
            },
        );
        let a = c.get_or_compile(2).unwrap();
        let s = a.sharded.as_ref().expect("multi-core compile attaches a sharding");
        assert!(s.interval_seconds() > 0.0);
        // the no-cut vector is always a candidate, so the sharded
        // interval can never lose to the single-pipeline service time
        assert!(s.interval_seconds() <= a.service_seconds);
        assert_eq!(s.stages.len(), s.transfer_bytes.len());
        // a single-core cache never pays for the cut search
        let mut c1 = PlanCache::new(
            "mlp",
            PlanCacheConfig {
                accel: AccelConfig::tiny(8 * 1024),
                joint: false,
                verify: true,
                max_entries: 0,
            },
        );
        assert!(c1.get_or_compile(2).unwrap().sharded.is_none());
    }
}
