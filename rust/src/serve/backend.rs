//! The planned backend: serving over compiled plan-cache artifacts.
//!
//! `PlannedBackend` implements the coordinator's [`Backend`] trait
//! over a set of batch-size buckets from the [`super::plans`] cache.
//! Its `infer` is a **service-time model**, not a numeric kernel: it
//! routes the batch to the smallest bucket that fits, then replays
//! that bucket's pipelined execution time (`service_seconds`, equal by
//! calibration to `simulate_pipelined`'s latency for the bucket's
//! `(Program, MemoryPlan)`). A batch larger than every compiled
//! bucket is **split** ([`PlannedBackend::route`]) into back-to-back
//! chunks — largest bucket repeatedly, remainder to the smallest
//! bucket that fits — rather than silently truncated or rejected;
//! only an empty batch is an error. End-to-end serving numbers
//! therefore reflect exactly the memory behavior the optimizer
//! predicted.
//! Output values are a deterministic placeholder (first input element
//! × 2 per request) — value correctness is the interpreter's and the
//! PJRT runtime's domain, not the serving simulator's.
//!
//! The backend also publishes its per-bucket cost table
//! ([`Backend::bucket_costs`]), which switches the server's flush
//! policy to cost-aware bucketized batching.

use super::loadsim::{choose_placement, PipelinedBucket, Placement};
use super::plans::PlannedArtifact;
use crate::coordinator::{Backend, BatchActuals, BucketCost};
use crate::util::error::Result;
use std::sync::Arc;
use std::time::Duration;

/// Serves a model from precompiled batch-size buckets, modeling each
/// batch's service time as its bucket's pipelined replay latency.
pub struct PlannedBackend {
    /// Bucket artifacts, sorted ascending by batch size.
    buckets: Vec<Arc<PlannedArtifact>>,
    /// Wall-clock seconds slept per modeled service second (1.0 =
    /// real time; 0.0 disables sleeping for tests).
    time_scale: f64,
    /// Replay actuals of the most recent `infer` (for the server's
    /// cost-drift auditor).
    last_actuals: Option<BatchActuals>,
}

impl PlannedBackend {
    pub fn new(mut buckets: Vec<Arc<PlannedArtifact>>) -> Result<PlannedBackend> {
        crate::ensure!(!buckets.is_empty(), "planned backend needs at least one bucket");
        buckets.sort_by_key(|a| a.batch);
        for w in buckets.windows(2) {
            crate::ensure!(
                w[0].batch != w[1].batch,
                "duplicate bucket batch {}",
                w[0].batch
            );
            crate::ensure!(
                w[0].in_len == w[1].in_len && w[0].out_len == w[1].out_len,
                "buckets disagree on per-request shape: b{} is {}→{}, b{} is {}→{}",
                w[0].batch,
                w[0].in_len,
                w[0].out_len,
                w[1].batch,
                w[1].in_len,
                w[1].out_len
            );
        }
        Ok(PlannedBackend { buckets, time_scale: 1.0, last_actuals: None })
    }

    /// Scale (or zero out) the modeled service sleeps.
    pub fn with_time_scale(mut self, scale: f64) -> PlannedBackend {
        self.time_scale = scale.max(0.0);
        self
    }

    /// The smallest bucket serving `n` requests (the largest bucket
    /// when `n` exceeds every bucket — `route` splits such batches
    /// before they get here).
    pub fn bucket_for(&self, n: usize) -> &Arc<PlannedArtifact> {
        self.buckets
            .iter()
            .find(|a| a.batch as usize >= n)
            .unwrap_or_else(|| self.buckets.last().expect("non-empty by construction"))
    }

    /// How an `n`-request batch maps onto the compiled buckets: chunk
    /// sizes served back to back, in submission order. A batch no
    /// bucket can hold is split — the largest bucket repeatedly, then
    /// the remainder to the smallest bucket that fits — instead of
    /// being rejected; an empty batch is an explicit error.
    pub fn route(&self, n: usize) -> Result<Vec<usize>> {
        crate::ensure!(n >= 1, "cannot route an empty batch");
        let cap = self.max_batch();
        let mut chunks = Vec::with_capacity(n / cap + 1);
        let mut rem = n;
        while rem > cap {
            chunks.push(cap);
            rem -= cap;
        }
        chunks.push(rem);
        Ok(chunks)
    }

    pub fn buckets(&self) -> &[Arc<PlannedArtifact>] {
        &self.buckets
    }

    /// Per-core placement of this model on a `cores`-core chip, by the
    /// amortized-cost rule over the largest (saturation) bucket:
    /// `cores` independent replicas complete a batch every
    /// `service / cores` seconds, the sharded pipeline one every
    /// `interval`. Without a compiled sharding (single-core cache)
    /// the answer is always replicas.
    pub fn placement(&self, cores: usize) -> Placement {
        let art = self.buckets.last().expect("non-empty by construction");
        match (&art.sharded, cores > 1) {
            (Some(s), true) => choose_placement(art.service_seconds, s.interval_seconds(), cores),
            _ => Placement::Replicas(cores.max(1)),
        }
    }

    /// The bucket table under the placement's service model: sharded
    /// placements admit a flush every pipeline interval, everything
    /// else every service time (what `run_load_pipelined` consumes).
    pub fn pipelined_buckets(&self, placement: Placement) -> Vec<PipelinedBucket> {
        self.buckets
            .iter()
            .map(|a| PipelinedBucket {
                cost: BucketCost {
                    batch: a.batch as usize,
                    offchip_bytes: a.cost.offchip_total(),
                    service_seconds: a.service_seconds,
                },
                interval_seconds: match (placement, &a.sharded) {
                    (Placement::Sharded, Some(s)) => s.interval_seconds(),
                    _ => a.service_seconds,
                },
            })
            .collect()
    }
}

impl Backend for PlannedBackend {
    fn input_len(&self) -> usize {
        self.buckets[0].in_len
    }

    fn output_len(&self) -> usize {
        self.buckets[0].out_len
    }

    fn max_batch(&self) -> usize {
        self.buckets.last().expect("non-empty").batch as usize
    }

    fn bucket_costs(&self) -> Option<Vec<BucketCost>> {
        Some(
            self.buckets
                .iter()
                .map(|a| BucketCost {
                    batch: a.batch as usize,
                    offchip_bytes: a.cost.offchip_total(),
                    service_seconds: a.service_seconds,
                })
                .collect(),
        )
    }

    fn last_batch_actuals(&self) -> Option<BatchActuals> {
        self.last_actuals
    }

    fn infer(&mut self, batch: &[f32], n: usize) -> Result<Vec<f32>> {
        let in_len = self.input_len();
        let out_len = self.output_len();
        crate::ensure!(batch.len() == n * in_len, "bad batch packing");
        let chunks = self.route(n)?;
        let mut service = 0.0f64;
        let mut replayed_bytes = 0i64;
        let mut replayed_seconds = 0.0f64;
        let mut bucket_batch = 0usize;
        for &c in &chunks {
            let art = self.bucket_for(c);
            service += art.service_seconds;
            replayed_bytes += art.replayed_offchip_bytes;
            replayed_seconds += art.replayed_seconds;
            bucket_batch = bucket_batch.max(art.batch as usize);
        }
        // report the *replayed* numbers, not the predicted ones (the
        // drift auditor's whole point is comparing the two), summed
        // over every chunk an oversized batch split into
        self.last_actuals = Some(BatchActuals {
            bucket_batch,
            offchip_bytes: replayed_bytes,
            service_seconds: replayed_seconds,
        });
        let service = service * self.time_scale;
        if service > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(service));
        }
        // deterministic placeholder payload (see module docs)
        let mut out = vec![0f32; n * out_len];
        for (k, row) in out.chunks_mut(out_len).enumerate() {
            row.fill(2.0 * batch[k * in_len]);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::AccelConfig;
    use crate::serve::plans::{PlanCache, PlanCacheConfig};

    fn backend() -> PlannedBackend {
        let mut cache = PlanCache::new(
            "mlp",
            PlanCacheConfig {
                accel: AccelConfig::tiny(64 * 1024),
                joint: false,
                verify: true,
                max_entries: 0,
            },
        );
        let arts = cache.compile_buckets(&[1, 2, 4]).unwrap();
        PlannedBackend::new(arts).unwrap().with_time_scale(0.0)
    }

    #[test]
    fn route_splits_oversized_batches_and_rejects_empty() {
        let be = backend();
        assert!(be.route(0).is_err());
        assert_eq!(be.route(1).unwrap(), vec![1]);
        assert_eq!(be.route(3).unwrap(), vec![3]);
        assert_eq!(be.route(4).unwrap(), vec![4]);
        assert_eq!(be.route(10).unwrap(), vec![4, 4, 2]);
    }

    #[test]
    fn oversized_infer_splits_and_aggregates_actuals() {
        let mut be = backend();
        let in_len = be.input_len();
        let out_len = be.output_len();
        let n = 10usize; // routes as 4 + 4 + 2
        let batch: Vec<f32> = (0..n * in_len).map(|i| i as f32).collect();
        let out = be.infer(&batch, n).unwrap();
        assert_eq!(out.len(), n * out_len);
        for k in 0..n {
            assert_eq!(out[k * out_len], 2.0 * batch[k * in_len]);
        }
        let b4 = be.bucket_for(4).clone();
        let b2 = be.bucket_for(2).clone();
        let acts = be.last_batch_actuals().unwrap();
        assert_eq!(acts.bucket_batch, 4);
        assert_eq!(
            acts.offchip_bytes,
            2 * b4.replayed_offchip_bytes + b2.replayed_offchip_bytes
        );
        assert_eq!(
            acts.service_seconds,
            b4.replayed_seconds + b4.replayed_seconds + b2.replayed_seconds
        );
        // in-range batches keep the single-bucket fast path
        let small = vec![1.0f32; 3 * in_len];
        be.infer(&small, 3).unwrap();
        let acts = be.last_batch_actuals().unwrap();
        assert_eq!(acts.bucket_batch, 4);
        assert_eq!(acts.offchip_bytes, b4.replayed_offchip_bytes);
    }

    #[test]
    fn empty_batch_is_an_explicit_error() {
        let mut be = backend();
        assert!(be.infer(&[], 0).is_err());
    }

    #[test]
    fn placement_follows_the_amortized_cost_rule() {
        let mut cache = PlanCache::new(
            "mlp",
            PlanCacheConfig {
                accel: AccelConfig::tiny(8 * 1024).with_cores(2),
                joint: false,
                verify: true,
                max_entries: 0,
            },
        );
        let arts = cache.compile_buckets(&[1, 2]).unwrap();
        let be = PlannedBackend::new(arts).unwrap();
        let top = be.buckets().last().unwrap().clone();
        let s = top.sharded.as_ref().expect("multi-core compile attaches a sharding");
        assert_eq!(
            be.placement(2),
            choose_placement(top.service_seconds, s.interval_seconds(), 2)
        );
        assert_eq!(be.placement(1), Placement::Replicas(1));
        // the pipelined bucket table mirrors the placement's admission
        // period: sharded flushes every interval, replicas every
        // service time
        let sharded_tab = be.pipelined_buckets(Placement::Sharded);
        assert_eq!(
            sharded_tab.last().unwrap().interval_seconds,
            s.interval_seconds()
        );
        for b in &be.pipelined_buckets(Placement::Replicas(2)) {
            assert_eq!(b.interval_seconds, b.cost.service_seconds);
        }
    }
}
