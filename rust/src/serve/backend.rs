//! The planned backend: serving over compiled plan-cache artifacts.
//!
//! `PlannedBackend` implements the coordinator's [`Backend`] trait
//! over a set of batch-size buckets from the [`super::plans`] cache.
//! Its `infer` is a **service-time model**, not a numeric kernel: it
//! routes the batch to the smallest bucket that fits, then replays
//! that bucket's pipelined execution time (`service_seconds`, equal by
//! calibration to `simulate_pipelined`'s latency for the bucket's
//! `(Program, MemoryPlan)`). End-to-end serving numbers therefore
//! reflect exactly the memory behavior the optimizer predicted.
//! Output values are a deterministic placeholder (first input element
//! × 2 per request) — value correctness is the interpreter's and the
//! PJRT runtime's domain, not the serving simulator's.
//!
//! The backend also publishes its per-bucket cost table
//! ([`Backend::bucket_costs`]), which switches the server's flush
//! policy to cost-aware bucketized batching.

use super::plans::PlannedArtifact;
use crate::coordinator::{Backend, BatchActuals, BucketCost};
use crate::util::error::Result;
use std::sync::Arc;
use std::time::Duration;

/// Serves a model from precompiled batch-size buckets, modeling each
/// batch's service time as its bucket's pipelined replay latency.
pub struct PlannedBackend {
    /// Bucket artifacts, sorted ascending by batch size.
    buckets: Vec<Arc<PlannedArtifact>>,
    /// Wall-clock seconds slept per modeled service second (1.0 =
    /// real time; 0.0 disables sleeping for tests).
    time_scale: f64,
    /// Replay actuals of the most recent `infer` (for the server's
    /// cost-drift auditor).
    last_actuals: Option<BatchActuals>,
}

impl PlannedBackend {
    pub fn new(mut buckets: Vec<Arc<PlannedArtifact>>) -> Result<PlannedBackend> {
        crate::ensure!(!buckets.is_empty(), "planned backend needs at least one bucket");
        buckets.sort_by_key(|a| a.batch);
        for w in buckets.windows(2) {
            crate::ensure!(
                w[0].batch != w[1].batch,
                "duplicate bucket batch {}",
                w[0].batch
            );
            crate::ensure!(
                w[0].in_len == w[1].in_len && w[0].out_len == w[1].out_len,
                "buckets disagree on per-request shape: b{} is {}→{}, b{} is {}→{}",
                w[0].batch,
                w[0].in_len,
                w[0].out_len,
                w[1].batch,
                w[1].in_len,
                w[1].out_len
            );
        }
        Ok(PlannedBackend { buckets, time_scale: 1.0, last_actuals: None })
    }

    /// Scale (or zero out) the modeled service sleeps.
    pub fn with_time_scale(mut self, scale: f64) -> PlannedBackend {
        self.time_scale = scale.max(0.0);
        self
    }

    /// The smallest bucket serving `n` requests (the largest bucket
    /// when `n` exceeds every bucket — callers cap `n` at
    /// `max_batch`).
    pub fn bucket_for(&self, n: usize) -> &Arc<PlannedArtifact> {
        self.buckets
            .iter()
            .find(|a| a.batch as usize >= n)
            .unwrap_or_else(|| self.buckets.last().expect("non-empty by construction"))
    }

    pub fn buckets(&self) -> &[Arc<PlannedArtifact>] {
        &self.buckets
    }
}

impl Backend for PlannedBackend {
    fn input_len(&self) -> usize {
        self.buckets[0].in_len
    }

    fn output_len(&self) -> usize {
        self.buckets[0].out_len
    }

    fn max_batch(&self) -> usize {
        self.buckets.last().expect("non-empty").batch as usize
    }

    fn bucket_costs(&self) -> Option<Vec<BucketCost>> {
        Some(
            self.buckets
                .iter()
                .map(|a| BucketCost {
                    batch: a.batch as usize,
                    offchip_bytes: a.cost.offchip_total(),
                    service_seconds: a.service_seconds,
                })
                .collect(),
        )
    }

    fn last_batch_actuals(&self) -> Option<BatchActuals> {
        self.last_actuals
    }

    fn infer(&mut self, batch: &[f32], n: usize) -> Result<Vec<f32>> {
        let in_len = self.input_len();
        let out_len = self.output_len();
        crate::ensure!(n >= 1, "empty batch");
        crate::ensure!(n <= self.max_batch(), "batch {n} exceeds largest bucket");
        crate::ensure!(batch.len() == n * in_len, "bad batch packing");
        let art = self.bucket_for(n).clone();
        let service = art.service_seconds * self.time_scale;
        // report the *replayed* numbers, not the predicted ones: the
        // drift auditor's whole point is comparing the two
        self.last_actuals = Some(BatchActuals {
            bucket_batch: art.batch as usize,
            offchip_bytes: art.replayed_offchip_bytes,
            service_seconds: art.replayed_seconds,
        });
        if service > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(service));
        }
        // deterministic placeholder payload (see module docs)
        let mut out = vec![0f32; n * out_len];
        for (k, row) in out.chunks_mut(out_len).enumerate() {
            row.fill(2.0 * batch[k * in_len]);
        }
        Ok(out)
    }
}
