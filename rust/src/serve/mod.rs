//! The production serving path: AOT plan cache → planned backend →
//! cost-aware bucketized batching → load simulation.
//!
//! This module connects the compile-time stack (`passes`, `opt`,
//! `cost`) to the runtime stack (`coordinator`):
//!
//! * [`plans`] — the AOT **plan cache**, keyed by
//!   `(model, batch, AccelConfig, decision)`: compiles and memoizes an
//!   optimized `(Program, MemoryPlan)` artifact per batch-size bucket,
//!   with the unified cost model's prediction verified bit-exact
//!   against the pipelined replay (the service-time contract).
//! * [`backend`] — [`PlannedBackend`], a coordinator `Backend` that
//!   routes each batch to the smallest fitting bucket and replays its
//!   predicted pipelined service time; it publishes the per-bucket
//!   cost table that switches the server's flush policy to cost-aware
//!   bucketized batching (`coordinator::choose_bucket`).
//! * [`loadsim`] — deterministic virtual-time load simulation
//!   (Poisson open loop and fixed-population closed loop) used by
//!   `bench_serving` to report p50/p99 latency, sustained QPS and
//!   off-chip bytes/request per bucket set at equal offered load;
//!   [`loadsim::run_load_pipelined`] generalizes it to multiple
//!   engines and the sharded interval/latency service model, and
//!   [`loadsim::choose_placement`] is the amortized-cost rule between
//!   per-core replicas and sharding one model across cores
//!   (`bench_multicore`, E7).

pub mod backend;
pub mod loadsim;
pub mod plans;

pub use backend::PlannedBackend;
pub use loadsim::{
    choose_placement, run_load, run_load_pipelined, run_load_traced, Arrivals, LoadReport,
    LoadSimConfig, PipelinedBucket, Placement, SloReport, SloSpec,
};
pub use plans::{PlanCache, PlanCacheConfig, PlanKey, PlannedArtifact, ShardedPlan};
