//! # polymem — polyhedral memory-access optimization for DL accelerators
//!
//! A production-shaped reproduction of *"Optimizing Memory-Access
//! Patterns for Deep Learning Accelerators"* (Zheng et al., AWS, 2020):
//! the two global polyhedral optimizations of the Inferentia/Neuron
//! compiler — **data-movement elimination** and **global memory-bank
//! mapping** — together with everything they need to run and be
//! evaluated end to end:
//!
//! * [`poly`] — integer quasi-affine algebra (the isl replacement):
//!   access-map composition and exact reverse.
//! * [`ir`] — a tensor-operator graph IR with per-operator affine
//!   loop-nest lowering (the paper's §2 program representation).
//! * [`passes`] — the paper's §2.1 DME and §2.2 bank-mapping passes,
//!   plus the liveness/allocation support they depend on.
//! * [`alloc`] — the static scratchpad planner: compile-time
//!   scheduling, `(bank, offset, size)` assignment and spill planning,
//!   producing the [`alloc::MemoryPlan`] the simulator's planned mode
//!   replays and verifies.
//! * [`tile`] — the polyhedral tiling subsystem: per-tile working-set
//!   analysis, strip-mining with fused producer→elementwise chains,
//!   and the double-buffered DMA pipeline schedule the simulator's
//!   pipelined mode replays.
//! * [`cost`] — the unified memory-access cost model: one analytic
//!   prediction of DRAM traffic and pipelined seconds per
//!   `(program, plan)` pair, byte-exact against the simulator's
//!   planned accounting, plus the shared decision-scoring policy the
//!   staged heuristics consult.
//! * [`opt`] — the whole-model joint optimizer: beam search with
//!   branch-and-bound over fusion/tiling/scheduling/spill decision
//!   vectors, each realized through the real pipeline and scored by
//!   [`cost`]; an optional pass-manager stage (`simulate --opt`).
//! * [`accel`] — a simulated Inferentia-class accelerator (banked
//!   scratchpad + DMA byte accounting) used as the measurement
//!   substrate for the paper's two experiments.
//! * [`interp`] — the reference scalar interpreter (semantic oracle)
//!   and the stage-by-stage differential equivalence harness that
//!   regression-tests every pass against it.
//! * [`models`] — ResNet-50, a Parallel-WaveNet-shaped graph, and other
//!   workload builders.
//! * [`obs`] — zero-dependency telemetry: counters, log-bucket
//!   histograms, phase timings and Chrome-trace export, compiled to
//!   no-ops when disabled; the byte-exact per-layer traffic
//!   attribution and engine timelines ride on it.
//! * [`runtime`] — PJRT execution of AOT-compiled JAX/Pallas artifacts
//!   (HLO text) from Rust.
//! * [`coordinator`] — a batching inference server over the runtime,
//!   with cost-aware bucketized flush sizing.
//! * [`serve`] — the production serving path: the AOT plan cache
//!   (memoized optimized `(Program, MemoryPlan)` artifacts per
//!   batch-size bucket), the planned backend that replays predicted
//!   pipelined service times, and the deterministic closed-loop /
//!   Poisson load simulation behind `bench_serving`.
//! * [`shard`] — pipeline-parallel multi-core sharding: contiguous
//!   stage cuts over the scheduled graph searched jointly with the
//!   per-stage memory plans, the inter-core transfer cost model
//!   (`TrafficClass::InterCore`), and the multi-engine replay that
//!   holds the sharded prediction byte-/bit-exact.
//! * [`report`] — paper-table formatting for the benchmark harness.
//! * [`util`] — offline substitutes for clap/serde/criterion/proptest.
//!
//! See `DESIGN.md` for the module map and plan-format invariants, and
//! `EXPERIMENTS.md` for the experiment index (how each paper table is
//! regenerated and where the measured numbers come from).


pub mod accel;
pub mod alloc;
pub mod coordinator;
pub mod cost;
pub mod interp;
pub mod ir;
pub mod models;
pub mod obs;
pub mod opt;
pub mod passes;
pub mod poly;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod shard;
pub mod tile;
pub mod util;
