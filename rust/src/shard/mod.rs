//! Pipeline-parallel model sharding across cores.
//!
//! The chip the paper targets has several cores; everything upstream
//! of this module compiles for exactly one. Sharding splits the
//! scheduled graph into `k ≤ num_cores` **contiguous stages** (node
//! ranges in the builder's topological order), compiles each stage
//! through the full existing pass pipeline (lower → DME → opt/tile →
//! bank → plan) for its own core, and runs the stages as a software
//! pipeline: core `s` computes batch `b` while core `s-1` computes
//! batch `b+1`.
//!
//! **The 3-hop transfer model.** A tensor cut by a stage boundary is
//! (1) written back to the producer core's DRAM — its stage graph
//! marks it `Output`, so the stage pays a normal `OutputStore`; (2)
//! shipped over the core-to-core fabric — charged here as
//! [`TrafficClass::InterCore`] bytes, once per boundary crossed, and
//! as `transfer` seconds at `intercore_bps`; (3) loaded by the
//! consumer core — its stage graph marks it `Input`, a normal
//! `InputLoad`. Per-stage compilation, cost evaluation and simulation
//! therefore run **unchanged**, and the sharded prediction/replay pair
//! inherit the repo's calibration invariant: both sides combine
//! per-stage numbers through the single
//! [`cost::combine_sharded`] combiner, so traffic stays byte-exact and
//! seconds bit-exact ([`replay_sharded`] is the multi-engine replay).
//!
//! **The search.** [`search_sharded`] widens the joint decision space
//! with the cut-point axis: candidate boundaries are ranked by
//! crossing bytes (the `max_cut_points` cheapest kept), cut vectors
//! are enumerated for k = 1..=num_cores, and candidates are evaluated
//! in ascending branch-and-bound floor order (per-stage compulsory
//! DMA seconds + hand-off) so dominated cut vectors are pruned before
//! any stage compiles. Per-stage artifacts are memoized by node range
//! across cut vectors, and each stage's inner beam search reuses the
//! memoized two-tier realization + worker pool — so the widened search
//! stays affordable and, because the inner search is thread-count
//! invariant and the outer enumeration is serial and deterministic,
//! the sharded winner is too (extended in `tests/opt_threads.rs`).
//!
//! Interpreted semantics are preserved exactly: stage graphs keep the
//! original tensor/node ids, so per-tensor seeded buffers line up, and
//! [`interpret_sharded`] forwards cut tensors between stages —
//! the differential oracle (`tests/diff_pipeline.rs`) holds the
//! composition to bit-identical outputs against the unsharded
//! reference.

use crate::accel::engine;
use crate::accel::{simulate_pipelined, AccelConfig};
use crate::alloc::MemoryPlan;
use crate::cost::{combine_sharded, compulsory_offchip, evaluate, CostBreakdown, ShardedCost};
use crate::interp::{interpret, Buffers, InterpError};
use crate::ir::graph::Node;
use crate::ir::loopnest::Program;
use crate::ir::tensor::{TensorId, TensorInfo, TensorKind};
use crate::ir::Graph;
use crate::obs::ChromeTrace;
use crate::passes::dme::run_dme;
use crate::passes::{AllocStage, OptStage, PassManager, TileStage};
use crate::util::error::Result;
use crate::util::json::Json;
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;
use std::time::Instant;

/// How the shard search compiles and enumerates.
#[derive(Clone, Debug)]
pub struct ShardOpts {
    /// Joint beam search per stage (`opt` stage) vs staged-greedy
    /// tiling (`tile` stage); both end in the alloc stage.
    pub joint: bool,
    /// Inter-pass IR verification while compiling stages.
    pub verify: bool,
    /// Worker threads for each stage's inner beam search (0 = auto).
    pub threads: usize,
    /// Candidate cut positions kept (the cheapest boundaries by
    /// crossing bytes). Bounds the enumeration at
    /// `Σ_k C(max_cut_points, k-1)`.
    pub max_cut_points: usize,
}

impl Default for ShardOpts {
    fn default() -> ShardOpts {
        ShardOpts { joint: true, verify: false, threads: 0, max_cut_points: 8 }
    }
}

/// One compiled pipeline stage: the contiguous node range
/// `[start, end)` of the original graph, compiled through the full
/// pass pipeline for one core.
#[derive(Clone, Debug)]
pub struct StageArtifact {
    pub start: usize,
    pub end: usize,
    pub program: Program,
    pub plan: MemoryPlan,
    /// Unified cost-model prediction for this stage alone.
    pub cost: CostBreakdown,
    /// The stage's winning memory-plan decision vector.
    pub decision: String,
}

/// Search accounting (deterministic except `search_seconds`).
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    /// Cut vectors enumerated (including the k=1 no-cut vector).
    pub candidates: usize,
    /// Cut vectors fully evaluated (stages compiled + combined).
    pub evaluated: usize,
    /// Cut vectors skipped because their floor met or exceeded the
    /// incumbent interval.
    pub pruned: usize,
    /// Cut vectors dropped because a stage could not plan.
    pub infeasible: usize,
    /// Stage compilations actually run (memo misses).
    pub stage_compiles: usize,
    /// Stage compilations served from the range memo.
    pub memo_hits: usize,
    pub search_seconds: f64,
}

impl ShardStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("candidates", Json::Int(self.candidates as i64)),
            ("evaluated", Json::Int(self.evaluated as i64)),
            ("pruned", Json::Int(self.pruned as i64)),
            ("infeasible", Json::Int(self.infeasible as i64)),
            ("stage_compiles", Json::Int(self.stage_compiles as i64)),
            ("memo_hits", Json::Int(self.memo_hits as i64)),
            ("search_seconds", Json::Num(self.search_seconds)),
        ])
    }
}

/// The sharded winner: the cut decision, its per-stage artifacts, and
/// the combined multi-core prediction.
#[derive(Clone, Debug)]
pub struct ShardOutcome {
    /// Cut positions (node indices; empty = single stage).
    pub cuts: Vec<usize>,
    pub stages: Vec<Arc<StageArtifact>>,
    /// Fabric bytes each stage ships to its successor (last entry 0):
    /// the sizes of every tensor alive across that boundary.
    pub transfer_bytes: Vec<i64>,
    pub cost: ShardedCost,
    pub stats: ShardStats,
}

impl ShardOutcome {
    pub fn num_stages(&self) -> usize {
        self.stages.len()
    }

    /// The widened decision vector: the cut axis plus each stage's
    /// memory-plan decision.
    pub fn describe(&self) -> String {
        let stages: Vec<String> = self
            .stages
            .iter()
            .map(|s| format!("[{}..{}) {}", s.start, s.end, s.decision))
            .collect();
        format!("cuts={:?} | {}", self.cuts, stages.join(" | "))
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cuts", Json::Arr(self.cuts.iter().map(|&c| Json::Int(c as i64)).collect())),
            ("stages", Json::Int(self.num_stages() as i64)),
            (
                "transfer_bytes",
                Json::Arr(self.transfer_bytes.iter().map(|&b| Json::Int(b)).collect()),
            ),
            ("decision", Json::Str(self.describe())),
            ("cost", self.cost.to_json()),
            ("stats", self.stats.to_json()),
        ])
    }

    /// Chrome-trace export of the steady-state pipeline: one lane per
    /// core, `batches` batches through the pipe, compute spans plus
    /// the inter-core sends.
    pub fn to_chrome_json(&self, batches: usize) -> Json {
        let spans = engine::multicore_pipeline_intervals(
            &self.cost.stage_seconds,
            &self.cost.transfer_seconds,
            batches,
        );
        let mut ct = ChromeTrace::new();
        for (s, stage) in self.stages.iter().enumerate() {
            ct.thread_name(s as i64, &format!("core{} [{}..{})", s, stage.start, stage.end));
        }
        for sp in &spans {
            ct.span(sp.core as i64, &format!("b{} stage{}", sp.batch, sp.core), sp.start, sp.done - sp.start);
            if sp.sent > sp.done {
                ct.span(sp.core as i64, &format!("b{} send", sp.batch), sp.done, sp.sent - sp.done);
            }
        }
        ct.to_json()
    }
}

/// Bytes of every tensor alive across a cut at node index `cut`:
/// produced by a node `< cut`, consumed by a node `≥ cut`. These are
/// the tensors the fabric must ship at this boundary.
pub fn crossing_bytes(g: &Graph, cut: usize) -> i64 {
    crossing_tensors(g, cut).iter().map(|&t| g.tensor(t).size_bytes()).sum()
}

/// The tensors alive across a cut, in id order.
pub fn crossing_tensors(g: &Graph, cut: usize) -> Vec<TensorId> {
    let nodes = g.nodes();
    let mut produced_before: BTreeMap<TensorId, bool> = BTreeMap::new();
    for (i, n) in nodes.iter().enumerate() {
        produced_before.insert(n.output, i < cut);
    }
    let mut out: Vec<TensorId> = Vec::new();
    for n in nodes.iter().skip(cut) {
        for &t in &n.inputs {
            if produced_before.get(&t) == Some(&true) && !out.contains(&t) {
                out.push(t);
            }
        }
    }
    out.sort();
    out
}

/// Extract the stage subgraph for nodes `[start, end)`, preserving the
/// original tensor and node ids (so seeded buffers and cut identities
/// line up across stages). Kind rewrites at the boundary implement the
/// 3-hop model: tensors produced before `start` become stage `Input`s
/// (cut-ins), tensors produced in-stage but consumed at or after `end`
/// become stage `Output`s (cut-outs).
pub fn stage_graph(g: &Graph, start: usize, end: usize) -> Graph {
    let nodes = g.nodes();
    assert!(start < end && end <= nodes.len(), "stage range [{start}..{end})");
    let producer_pos: BTreeMap<TensorId, usize> =
        nodes.iter().enumerate().map(|(i, n)| (n.output, i)).collect();
    let mut last_use: BTreeMap<TensorId, usize> = BTreeMap::new();
    for (i, n) in nodes.iter().enumerate() {
        for &t in &n.inputs {
            last_use.insert(t, i);
        }
    }
    let mut tensors: BTreeMap<TensorId, TensorInfo> = BTreeMap::new();
    let mut keep = |g: &Graph, t: TensorId, tensors: &mut BTreeMap<TensorId, TensorInfo>| {
        if tensors.contains_key(&t) {
            return;
        }
        let mut info = g.tensor(t).clone();
        info.kind = match info.kind {
            TensorKind::Input => TensorKind::Input,
            TensorKind::Weight => TensorKind::Weight,
            kind => match producer_pos.get(&t) {
                // produced upstream: this stage receives it (cut-in)
                Some(&p) if p < start => TensorKind::Input,
                // produced here: keep Output; an intermediate consumed
                // downstream becomes a cut-out
                _ if kind == TensorKind::Output => TensorKind::Output,
                _ if last_use.get(&t).is_some_and(|&u| u >= end) => TensorKind::Output,
                _ => TensorKind::Intermediate,
            },
        };
        tensors.insert(t, info);
    };
    let mut stage_nodes: Vec<Node> = Vec::with_capacity(end - start);
    for n in &nodes[start..end] {
        for &t in &n.inputs {
            keep(g, t, &mut tensors);
        }
        keep(g, n.output, &mut tensors);
        stage_nodes.push(n.clone());
    }
    Graph::from_parts(tensors, stage_nodes)
}

/// The contiguous stage ranges a cut vector induces over `n` nodes.
pub fn stage_ranges(n: usize, cuts: &[usize]) -> Vec<(usize, usize)> {
    let mut bounds = Vec::with_capacity(cuts.len() + 2);
    bounds.push(0);
    bounds.extend_from_slice(cuts);
    bounds.push(n);
    bounds.windows(2).map(|w| (w[0], w[1])).collect()
}

/// Per-stage fabric bytes for a cut vector (last entry 0).
pub fn transfer_bytes(g: &Graph, cuts: &[usize]) -> Vec<i64> {
    let mut out: Vec<i64> = cuts.iter().map(|&c| crossing_bytes(g, c)).collect();
    out.push(0);
    out
}

fn combinations(items: &[usize], k: usize) -> Vec<Vec<usize>> {
    if k == 0 {
        return vec![vec![]];
    }
    let mut out = Vec::new();
    for (i, &first) in items.iter().enumerate() {
        if items.len() - i < k {
            break;
        }
        for mut rest in combinations(&items[i + 1..], k - 1) {
            rest.insert(0, first);
            out.push(rest);
        }
    }
    out
}

/// `a` strictly better than `b`: smaller steady-state interval, then
/// fewer off-chip bytes, then fewer fabric bytes, then fewer stages,
/// then lexicographically smaller cuts — a deterministic total order.
fn better(a: &ShardOutcome, b: &ShardOutcome) -> bool {
    if a.cost.interval_seconds != b.cost.interval_seconds {
        return a.cost.interval_seconds < b.cost.interval_seconds;
    }
    if a.cost.offchip_total() != b.cost.offchip_total() {
        return a.cost.offchip_total() < b.cost.offchip_total();
    }
    if a.cost.intercore_total() != b.cost.intercore_total() {
        return a.cost.intercore_total() < b.cost.intercore_total();
    }
    if a.num_stages() != b.num_stages() {
        return a.num_stages() < b.num_stages();
    }
    a.cuts < b.cuts
}

struct SearchMemo {
    /// Compiled stage artifacts by node range (Err = cannot plan).
    stages: HashMap<(usize, usize), std::result::Result<Arc<StageArtifact>, String>>,
    /// Branch-and-bound floors by node range: compulsory off-chip DMA
    /// seconds of the post-DME stage program.
    floors: HashMap<(usize, usize), f64>,
}

fn stage_floor(g: &Graph, range: (usize, usize), cfg: &AccelConfig, memo: &mut SearchMemo) -> f64 {
    if let Some(&f) = memo.floors.get(&range) {
        return f;
    }
    let sg = stage_graph(g, range.0, range.1);
    let mut p = Program::lower(sg);
    run_dme(&mut p);
    let f = compulsory_offchip(&p) as f64 / cfg.dram_bps;
    memo.floors.insert(range, f);
    f
}

fn compile_stage(
    g: &Graph,
    range: (usize, usize),
    cfg: &AccelConfig,
    opts: &ShardOpts,
    memo: &mut SearchMemo,
    stats: &mut ShardStats,
) -> std::result::Result<Arc<StageArtifact>, String> {
    if let Some(r) = memo.stages.get(&range) {
        stats.memo_hits += 1;
        return r.clone();
    }
    stats.stage_compiles += 1;
    let sg = stage_graph(g, range.0, range.1);
    let pm = PassManager {
        opt: opts
            .joint
            .then(|| OptStage::for_accel(cfg.clone()).with_threads(opts.threads)),
        tile: (!opts.joint).then(|| TileStage::for_accel(cfg.clone())),
        alloc: Some(AllocStage::for_accel(cfg.clone())),
        verify: opts.verify,
        ..PassManager::default()
    };
    let built = match pm.run(sg) {
        Err(e) => Err(format!("stage [{}..{}): {e}", range.0, range.1)),
        Ok(rep) => {
            let decision = rep
                .opt
                .as_ref()
                .map(|s| s.decision.clone())
                .unwrap_or_else(|| crate::cost::DecisionVector::baseline().describe());
            let program = rep.program;
            let plan = rep.plan.expect("alloc stage always configured");
            let cost = evaluate(&program, &plan, cfg);
            Ok(Arc::new(StageArtifact {
                start: range.0,
                end: range.1,
                program,
                plan,
                cost,
                decision,
            }))
        }
    };
    memo.stages.insert(range, built.clone());
    built
}

/// Search cut vectors × per-stage memory plans for the sharding that
/// minimizes the steady-state batch interval on `cfg.num_cores` cores.
/// `k = 1` (no cut) is always a candidate, so the winner is never
/// worse than the single-core plan under the same objective. The
/// result is deterministic and thread-count invariant.
pub fn search_sharded(g: &Graph, cfg: &AccelConfig, opts: &ShardOpts) -> Result<ShardOutcome> {
    let t0 = Instant::now();
    let n = g.nodes().len();
    crate::ensure!(n >= 1, "shard search: empty graph");
    let cores = cfg.num_cores.max(1);
    let mut stats = ShardStats::default();
    let mut memo = SearchMemo { stages: HashMap::new(), floors: HashMap::new() };

    // candidate boundaries: the cheapest crossings first
    let mut scored: Vec<(i64, usize)> = (1..n).map(|p| (crossing_bytes(g, p), p)).collect();
    scored.sort();
    scored.truncate(opts.max_cut_points);
    let mut positions: Vec<usize> = scored.into_iter().map(|(_, p)| p).collect();
    positions.sort();

    // enumerate cut vectors for k = 1..=cores, with their floors
    let mut cands: Vec<(u64, Vec<usize>)> = Vec::new();
    for k in 1..=cores.min(positions.len() + 1) {
        for cuts in combinations(&positions, k - 1) {
            let transfers = transfer_bytes(g, &cuts);
            let floor = stage_ranges(n, &cuts)
                .iter()
                .zip(&transfers)
                .map(|(&r, &b)| stage_floor(g, r, cfg, &mut memo) + engine::intercore_seconds(cfg, b))
                .fold(0.0f64, f64::max);
            cands.push((floor.to_bits(), cuts));
        }
    }
    stats.candidates = cands.len();
    // ascending floor, then fewer cuts, then lexicographic: pruning
    // fires as early as possible and the scan order is deterministic
    cands.sort_by(|a, b| {
        f64::from_bits(a.0)
            .total_cmp(&f64::from_bits(b.0))
            .then(a.1.len().cmp(&b.1.len()))
            .then(a.1.cmp(&b.1))
    });

    let mut best: Option<ShardOutcome> = None;
    let mut first_err: Option<String> = None;
    for (floor_bits, cuts) in cands {
        if let Some(b) = &best {
            if f64::from_bits(floor_bits) >= b.cost.interval_seconds {
                stats.pruned += 1;
                continue;
            }
        }
        let ranges = stage_ranges(n, &cuts);
        let mut stages: Vec<Arc<StageArtifact>> = Vec::with_capacity(ranges.len());
        let mut failed = false;
        for &r in &ranges {
            match compile_stage(g, r, cfg, opts, &mut memo, &mut stats) {
                Ok(a) => stages.push(a),
                Err(e) => {
                    first_err.get_or_insert(e);
                    failed = true;
                    break;
                }
            }
        }
        if failed {
            stats.infeasible += 1;
            continue;
        }
        stats.evaluated += 1;
        let transfers = transfer_bytes(g, &cuts);
        let stage_seconds: Vec<f64> = stages.iter().map(|s| s.cost.pipelined_seconds).collect();
        let stage_traffic: Vec<&crate::accel::TrafficCounters> =
            stages.iter().map(|s| &s.cost.traffic).collect();
        let stage_peaks: Vec<i64> = stages.iter().map(|s| s.cost.peak_scratchpad).collect();
        let cost = combine_sharded(&stage_seconds, &stage_traffic, &stage_peaks, &transfers, cfg);
        let cand = ShardOutcome {
            cuts,
            stages,
            transfer_bytes: transfers,
            cost,
            stats: ShardStats::default(),
        };
        let take = match &best {
            None => true,
            Some(b) => better(&cand, b),
        };
        if take {
            best = Some(cand);
        }
    }
    stats.search_seconds = t0.elapsed().as_secs_f64();
    match best {
        Some(mut b) => {
            b.stats = stats;
            Ok(b)
        }
        None => Err(crate::format_err!(
            "shard search: no feasible sharding ({})",
            first_err.unwrap_or_else(|| "no candidates".into())
        )),
    }
}

/// Multi-engine replay of a sharded winner: each stage replays on its
/// own engine pair through `simulate_pipelined` (unchanged), and the
/// per-stage measurements combine through the *same*
/// [`combine_sharded`] recurrence as the prediction. The sharded
/// calibration contract: the result `bits_eq` the predicted
/// [`ShardedCost`].
pub fn replay_sharded(
    stages: &[Arc<StageArtifact>],
    transfer_bytes: &[i64],
    cfg: &AccelConfig,
) -> Result<ShardedCost> {
    let mut seconds = Vec::with_capacity(stages.len());
    let mut traffic = Vec::with_capacity(stages.len());
    let mut peaks = Vec::with_capacity(stages.len());
    for s in stages {
        let sim = simulate_pipelined(&s.program, &s.plan, cfg, None)
            .map_err(|e| crate::format_err!("sharded replay stage [{}..{}): {e}", s.start, s.end))?;
        seconds.push(sim.seconds);
        traffic.push(sim.traffic);
        peaks.push(sim.peak_scratchpad);
    }
    let refs: Vec<&crate::accel::TrafficCounters> = traffic.iter().collect();
    Ok(combine_sharded(&seconds, &refs, &peaks, transfer_bytes, cfg))
}

/// Run the compiled stages end to end on the scalar interpreter,
/// forwarding cut tensors between stages, and return the final values
/// of the original graph's outputs. Stage graphs preserve tensor ids
/// and `Buffers::seeded` seeds per tensor id, so the only values that
/// need forwarding are the cut-ins (stage `Input`s some earlier stage
/// produced). The differential oracle compares this bit-for-bit with
/// the unsharded reference.
pub fn interpret_sharded(
    stages: &[Arc<StageArtifact>],
    outputs: &[TensorId],
    seed: u64,
) -> std::result::Result<BTreeMap<TensorId, Vec<f64>>, InterpError> {
    let mut forwarded: BTreeMap<TensorId, Vec<f64>> = BTreeMap::new();
    for s in stages {
        let g = &s.program.graph;
        let mut bufs = Buffers::seeded(g, seed);
        for t in g.tensors() {
            if t.kind == TensorKind::Input {
                if let Some(vals) = forwarded.get(&t.id) {
                    bufs.set_tensor(t.id, vals.clone());
                }
            }
        }
        interpret(&s.program, &mut bufs)?;
        for t in g.tensors() {
            if t.kind == TensorKind::Output {
                forwarded.insert(t.id, bufs.tensor(t.id).to_vec());
            }
        }
    }
    Ok(outputs
        .iter()
        .map(|&t| (t, forwarded.get(&t).cloned().unwrap_or_default()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interp::diff::stage_outputs;
    use crate::models;

    fn tiny_cfg(cores: usize) -> AccelConfig {
        AccelConfig::tiny(8 * 1024).with_cores(cores)
    }

    fn greedy_opts() -> ShardOpts {
        // staged-greedy per stage keeps unit tests fast; the joint
        // path is covered by opt_threads / diff_pipeline / benches
        ShardOpts { joint: false, verify: true, ..ShardOpts::default() }
    }

    #[test]
    fn stage_graphs_partition_and_rewrite_kinds() {
        let g = models::mlp(2, 12, 8, 4, 2);
        let n = g.nodes().len();
        let cut = n / 2;
        let a = stage_graph(&g, 0, cut);
        let b = stage_graph(&g, cut, n);
        assert_eq!(a.nodes().len() + b.nodes().len(), n);
        crate::ir::verify::verify_graph(&a).unwrap();
        crate::ir::verify::verify_graph(&b).unwrap();
        // every crossing tensor is an Output upstream and an Input
        // downstream, under its original id
        let crossing = crossing_tensors(&g, cut);
        assert!(!crossing.is_empty());
        for t in crossing {
            assert_eq!(a.tensor(t).kind, TensorKind::Output, "{t:?} upstream");
            assert_eq!(b.tensor(t).kind, TensorKind::Input, "{t:?} downstream");
        }
    }

    #[test]
    fn crossing_bytes_match_manual_count() {
        let g = models::mlp(2, 12, 8, 4, 2);
        for cut in 1..g.nodes().len() {
            let manual: i64 = crossing_tensors(&g, cut)
                .iter()
                .map(|&t| g.tensor(t).size_bytes())
                .sum();
            assert_eq!(crossing_bytes(&g, cut), manual);
        }
    }

    #[test]
    fn stage_ranges_cover() {
        assert_eq!(stage_ranges(10, &[]), vec![(0, 10)]);
        assert_eq!(stage_ranges(10, &[3, 7]), vec![(0, 3), (3, 7), (7, 10)]);
    }

    #[test]
    fn combinations_count() {
        let v = [1, 2, 3, 4];
        assert_eq!(combinations(&v, 0).len(), 1);
        assert_eq!(combinations(&v, 2).len(), 6);
        assert_eq!(combinations(&v, 4).len(), 1);
        assert_eq!(combinations(&v, 5).len(), 0);
        // lexicographic order
        assert_eq!(combinations(&v, 2)[0], vec![1, 2]);
    }

    #[test]
    fn search_single_core_is_one_stage() {
        let g = models::mlp(2, 12, 8, 4, 2);
        let out = search_sharded(&g, &tiny_cfg(1), &greedy_opts()).unwrap();
        assert_eq!(out.num_stages(), 1);
        assert!(out.cuts.is_empty());
        assert_eq!(out.cost.intercore_total(), 0);
        assert_eq!(out.transfer_bytes, vec![0]);
        // one stage: interval == latency == the stage's pipelined time
        assert_eq!(
            out.cost.interval_seconds.to_bits(),
            out.stages[0].cost.pipelined_seconds.to_bits()
        );
    }

    #[test]
    fn search_multicore_beats_or_ties_single_and_calibrates() {
        let g = models::resnet18_scaled(1, 16, 8, 10);
        let cfg = tiny_cfg(2);
        let out = search_sharded(&g, &cfg, &greedy_opts()).unwrap();
        let single = search_sharded(&g, &tiny_cfg(1), &greedy_opts()).unwrap();
        assert!(out.cost.interval_seconds <= single.cost.interval_seconds);
        assert!(out.num_stages() <= 2);
        // the multi-engine replay agrees byte-exactly / bit-exactly
        let replay = replay_sharded(&out.stages, &out.transfer_bytes, &cfg).unwrap();
        assert!(out.cost.bits_eq(&replay), "sharded calibration broke");
        if out.num_stages() == 2 {
            assert!(out.cost.intercore_total() > 0);
            assert!(out.cost.latency_seconds > out.cost.interval_seconds);
        }
        // stats add up
        let st = &out.stats;
        assert_eq!(st.candidates, st.evaluated + st.pruned + st.infeasible);
        assert!(st.stage_compiles > 0);
    }

    #[test]
    fn sharded_interpretation_is_bit_identical() {
        let seed = 0xD1FF_5EED;
        for (name, g) in [
            ("mlp", models::mlp(2, 12, 8, 4, 2)),
            ("resnet18", models::resnet18_scaled(1, 16, 8, 10)),
        ] {
            let cfg = tiny_cfg(2);
            let out = search_sharded(&g, &cfg, &greedy_opts()).unwrap();
            let outputs = g.outputs();
            let reference =
                stage_outputs(&Program::lower(g), &outputs, seed, "reference").unwrap();
            let sharded = interpret_sharded(&out.stages, &outputs, seed).unwrap();
            for (&t, vals) in &reference {
                let got = &sharded[&t];
                assert_eq!(vals.len(), got.len(), "{name} {t:?} length");
                for (i, (a, b)) in vals.iter().zip(got).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "{name} {t:?}[{i}]");
                }
            }
        }
    }

    #[test]
    fn chrome_export_has_one_lane_per_core() {
        let g = models::resnet18_scaled(1, 16, 8, 10);
        let cfg = tiny_cfg(2);
        let out = search_sharded(&g, &cfg, &greedy_opts()).unwrap();
        let j = out.to_chrome_json(3);
        let evs = j.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        assert!(!evs.is_empty());
    }
}
