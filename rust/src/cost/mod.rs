//! Unified memory-access cost model.
//!
//! Every memory decision this compiler makes — schedule order, fusion
//! grouping, per-group tile sizes, residency homes, spill victims —
//! used to be scored by a *local* proxy private to the pass that made
//! it: the scheduler minimized peak live bytes, the tile-size search
//! ranked grids by `(stream penalty, footprint)`, the spill planner
//! picked the largest idle gap. Each proxy is reasonable in isolation
//! and the combination is structurally unable to trade across stages
//! (a smaller tile that lets a *second* tensor stay staged; fusing
//! across a conv boundary with halo recompute instead of spilling the
//! intermediate). Following the combined-decision formulation of Li et
//! al. (arXiv 2311.18246) and the shared-cost-model framing of Zhang
//! et al. (arXiv 2105.12842), this module provides the one model all
//! of them consult:
//!
//! * [`model`] — [`model::evaluate`]: predicted DRAM traffic and
//!   pipelined seconds of a `(Program, MemoryPlan)` pair, as a pure
//!   function. The prediction is **calibrated to be byte-exact**
//!   against [`crate::accel::sim::simulate_planned`] /
//!   [`crate::accel::sim::simulate_pipelined`] — the calibration
//!   invariant `tests/prop_cost.rs` holds over every model builder and
//!   the fuzz corpus. The whole-model optimizer ([`crate::opt`])
//!   scores candidate decision vectors with it, so "fewer predicted
//!   bytes" *is* "fewer simulated bytes".
//! * [`policy`] — the [`policy::DecisionPolicy`] trait behind which
//!   the staged heuristics now score their candidates.
//!   [`policy::GreedyPolicy`] reproduces the historical local proxies
//!   bit-for-bit (the baseline mode and the search's seed candidate);
//!   [`policy::TrafficPolicy`] ranks spill victims by the DRAM bytes
//!   their eviction costs instead of gap length.
//! * [`decision`] — the whole-model [`decision::DecisionVector`]: the
//!   coordinates of one point in the joint decision space (tiling on /
//!   off, fusion policy, tile budget fraction, scheduler lookahead,
//!   spill flavor). [`crate::opt`] searches over these;
//!   [`decision::DecisionVector::baseline`] is exactly today's staged
//!   greedy configuration.

pub mod decision;
pub mod model;
pub mod policy;

pub use decision::{AllocDecision, DecisionVector, TileDecision};
pub use model::{combine_sharded, compulsory_offchip, evaluate, CostBreakdown, ShardedCost};
pub use policy::{DecisionPolicy, GreedyPolicy, TrafficPolicy};
