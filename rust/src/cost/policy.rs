//! The shared decision-scoring policy.
//!
//! The staged heuristics — the min-footprint scheduler
//! (`alloc/schedule.rs`), the tile-size grid search (`tile/mod.rs`)
//! and the spill victim selection (`alloc/spill.rs`) — no longer score
//! candidates with private inlined proxies: each consults a
//! [`DecisionPolicy`]. [`GreedyPolicy`] reproduces the historical
//! proxies exactly (it *is* today's behavior, and the joint search's
//! seed candidate); [`TrafficPolicy`] swaps the spill victim rule for
//! a DRAM-byte-cost ranking, one of the axes the whole-model optimizer
//! ([`crate::opt`]) explores. Keeping the scoring behind one trait is
//! what lets a future policy route *all* of these through the full
//! [`crate::cost::model`] without touching the passes again.

use crate::accel::config::AccelConfig;
use crate::ir::loopnest::Program;
use crate::ir::tensor::{TensorId, TensorKind};
use crate::tile::{chain_stream_penalty, chain_tile_footprint, Chain};

/// How each staged memory decision scores its candidates.
///
/// All keys are ordered tuples; *lower is better* for
/// [`Self::tile_grid_key`] and [`Self::schedule_key`], *higher is
/// better* for [`Self::spill_victim_key`] (matching each call site's
/// historical comparison direction).
pub trait DecisionPolicy {
    /// Key of candidate grid sizes `s` for `chain`. By contract `.1`
    /// is the candidate's double-buffered tile footprint in bytes (the
    /// grid search also checks it against the budget).
    fn tile_grid_key(
        &self,
        prog: &Program,
        chain: &Chain,
        s: &[i64],
        cfg: &AccelConfig,
    ) -> (i64, i64) {
        (
            chain_stream_penalty(prog, chain, s, cfg),
            chain_tile_footprint(prog, chain, s),
        )
    }

    /// Key of one schedule candidate: the peak over the lookahead
    /// horizon, tie-broken by the immediate footprint.
    fn schedule_key(&self, horizon_peak: i64, after: i64) -> (i64, i64) {
        (horizon_peak, after)
    }

    /// Key of a spill victim candidate whose usable idle gap is
    /// `gap = (from, to)`. Higher wins.
    fn spill_victim_key(&self, prog: &Program, t: TensorId, gap: (usize, usize)) -> (i64, i64) {
        let _ = (prog, t);
        ((gap.1 - gap.0) as i64, 0)
    }
}

/// The historical staged-greedy proxies, verbatim: footprint-ranked
/// schedules, `(stream penalty, footprint)`-ranked grids,
/// furthest-next-use (largest gap) spill victims.
#[derive(Clone, Copy, Debug, Default)]
pub struct GreedyPolicy;

impl DecisionPolicy for GreedyPolicy {}

/// Traffic-aware spill victims: rank by the DRAM bytes the eviction
/// will cost — a clean input/weight costs one re-stage, an
/// intermediate costs a spill write plus a reload — preferring the
/// cheapest eviction, gap length as the tie-break. Grid and schedule
/// scoring stay greedy.
#[derive(Clone, Copy, Debug, Default)]
pub struct TrafficPolicy;

impl DecisionPolicy for TrafficPolicy {
    fn spill_victim_key(&self, prog: &Program, t: TensorId, gap: (usize, usize)) -> (i64, i64) {
        let info = prog.graph.tensor(t);
        let cost = match info.kind {
            TensorKind::Input | TensorKind::Weight => info.size_bytes(),
            _ => 2 * info.size_bytes(),
        };
        (-cost, (gap.1 - gap.0) as i64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;

    #[test]
    fn greedy_keys_match_historical_proxies() {
        let g = GreedyPolicy;
        assert_eq!(g.schedule_key(10, 4), (10, 4));
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[8, 8]);
        let t = b.transpose("t", x, &[1, 0]);
        b.mark_output(t);
        let prog = Program::lower(b.finish());
        assert_eq!(g.spill_victim_key(&prog, x, (2, 7)), (5, 0));
    }

    #[test]
    fn traffic_policy_prefers_cheap_evictions() {
        // a weight (one re-stage) must outrank an equally-gapped
        // intermediate of the same size (spill + reload)
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[8, 8]);
        let w = b.weight("w", &[8, 8]);
        let m = b.matmul("m", x, w);
        let t = b.transpose("t", m, &[1, 0]);
        b.mark_output(t);
        let prog = Program::lower(b.finish());
        let p = TrafficPolicy;
        let kw = p.spill_victim_key(&prog, w, (0, 5));
        let km = p.spill_victim_key(&prog, m, (0, 5));
        assert!(kw > km, "weight {kw:?} should outrank intermediate {km:?}");
    }
}
