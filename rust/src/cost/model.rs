//! The analytic traffic/latency model.
//!
//! [`evaluate`] walks a planned program once and predicts, per the
//! plan's residency decisions, every DRAM and scratchpad byte the
//! planned replay will charge plus both latency estimates (serial
//! `max(compute, dma)` per step, and the double-buffered pipeline
//! model over tile-group runs). It is the replay's accounting
//! *re-derived as a pure function* — no scratchpad state machine, no
//! trace, no plan verification — which is what makes it cheap enough
//! for the joint optimizer to call once per candidate decision vector.
//! The re-derivation (rather than sharing one accounting walker with
//! `accel/sim.rs`) is deliberate: two independent implementations are
//! what give the calibration property test its teeth — a shared
//! walker would make `prop_cost` a tautology. The price is that any
//! accounting change in `sim.rs` must be mirrored here, with the
//! fuzzed calibration suite as the tripwire for a missed mirror.
//!
//! The contract (the **calibration invariant**, property-tested in
//! `tests/prop_cost.rs`):
//!
//! * `evaluate(p, plan, cfg).traffic` equals
//!   `simulate_planned(p, plan, cfg, None).traffic` byte-for-byte, per
//!   traffic class;
//! * `serial_seconds` equals `simulate_planned(..).seconds` and
//!   `pipelined_seconds` equals `simulate_pipelined(..).seconds`
//!   exactly (identical operation sequence, hence identical `f64`
//!   bits).
//!
//! The accounting rules mirrored here (see `accel/sim.rs` for the
//! authoritative prose): scratch-homed inputs/weights charge their
//! staging bytes at window start; tile-staged tensors never touch
//! DRAM; DRAM-homed tensors charge a full read per use — or, for tile
//! nests, the clipped image box of the tile, with a slice identical to
//! the one the same group's previous tile fetched charged once; copy
//! nests move on-chip when the destination is resident and spill
//! otherwise; compute nests with a non-resident output spill their
//! (tile or whole) store bytes; every graph output pays one write-back.

use crate::accel::config::AccelConfig;
use crate::accel::dma::{TrafficClass, TrafficCounters};
use crate::accel::engine;
use crate::alloc::{Home, MemoryPlan};
use crate::ir::loopnest::{Body, Program};
use crate::ir::op::OpKind;
use crate::ir::tensor::{TensorId, TensorKind};
use crate::tile::footprint::{nest_tensor_box, nest_tensor_bytes};
use crate::tile::pipeline::{run_steps, tile_runs, NestCost};
use crate::util::json::Json;
use std::collections::HashMap;

/// Predicted cost of one planned program.
#[derive(Clone, Debug)]
pub struct CostBreakdown {
    /// Predicted traffic, by class — byte-exact against the planned
    /// replay's counters.
    pub traffic: TrafficCounters,
    /// Scratchpad deposit bytes from staging DMA.
    pub staging_deposit_bytes: i64,
    /// Per-nest serial latency estimate (`simulate_planned`'s model).
    pub serial_seconds: f64,
    /// Double-buffered pipeline latency (`simulate_pipelined`'s model).
    pub pipelined_seconds: f64,
    /// Planned scratchpad high-water mark.
    pub peak_scratchpad: i64,
}

impl CostBreakdown {
    /// All predicted DRAM bytes — the joint optimizer's primary
    /// objective.
    pub fn offchip_total(&self) -> i64 {
        self.traffic.offchip_total()
    }

    /// All predicted data movement touching the scratchpad.
    pub fn onchip_movement_total(&self) -> i64 {
        self.staging_deposit_bytes + self.traffic.onchip_total()
    }

    /// Bit-exact equality: every traffic class byte-for-byte, the
    /// latency estimates compared on raw `f64` bits (`to_bits`, so
    /// NaN == NaN and -0.0 != 0.0). This is the bar the joint search's
    /// memoized scores are held to against the from-scratch
    /// realization path (`tests/opt_calibration.rs`).
    pub fn bits_eq(&self, other: &CostBreakdown) -> bool {
        self.traffic == other.traffic
            && self.staging_deposit_bytes == other.staging_deposit_bytes
            && self.serial_seconds.to_bits() == other.serial_seconds.to_bits()
            && self.pipelined_seconds.to_bits() == other.pipelined_seconds.to_bits()
            && self.peak_scratchpad == other.peak_scratchpad
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("offchip_total", Json::Int(self.offchip_total())),
            ("onchip_movement_total", Json::Int(self.onchip_movement_total())),
            ("serial_seconds", Json::Num(self.serial_seconds)),
            ("pipelined_seconds", Json::Num(self.pipelined_seconds)),
            ("peak_scratchpad", Json::Int(self.peak_scratchpad)),
        ])
    }
}

/// Predict the planned replay's traffic and latency for `(prog, plan)`
/// on `cfg`. The plan is trusted (callers hold plans produced by
/// [`crate::alloc::plan_memory`], which verify by construction); the
/// simulator remains the gatekeeper that re-verifies before replay.
pub fn evaluate(prog: &Program, plan: &MemoryPlan, cfg: &AccelConfig) -> CostBreakdown {
    let mut traffic = TrafficCounters::new();
    let mut staging_deposit_bytes = 0i64;
    let mut costs: Vec<NestCost> = Vec::with_capacity(prog.nests.len());
    // per (tile group, tensor): the slice box the last touching tile
    // fetched (weight-slice reuse across consecutive tiles) — the same
    // keying the planned replay uses.
    let mut last_box: HashMap<(u32, TensorId), (u32, Vec<(i64, i64)>)> = HashMap::new();
    let node_by_id: HashMap<_, _> =
        prog.graph.nodes().iter().map(|n| (n.id, n)).collect();

    for (pos, nest) in prog.nests.iter().enumerate() {
        let node = node_by_id[&nest.node];
        let mut off_in_bytes = 0i64;
        let mut off_out_bytes = 0i64;
        let mut on_bytes = 0i64;

        // ---- operands ----
        let mut operands: Vec<TensorId> = nest
            .body
            .loads()
            .iter()
            .flat_map(|l| l.pieces.iter().filter_map(|p| p.tensor))
            .collect();
        operands.sort();
        operands.dedup();
        for &t in &operands {
            let info = prog.graph.tensor(t);
            let w = plan.window_at(t, pos).expect("plan covers touched tensors");
            let staged_class = match info.kind {
                TensorKind::Weight => TrafficClass::WeightLoad,
                TensorKind::Input => TrafficClass::InputLoad,
                _ => TrafficClass::Reload,
            };
            match w.home {
                Home::Scratch(_) => {
                    let bytes = info.size_bytes();
                    let staged_here = w.start == pos
                        && matches!(info.kind, TensorKind::Input | TensorKind::Weight);
                    if staged_here {
                        traffic.add(staged_class, bytes);
                        off_in_bytes += bytes;
                        staging_deposit_bytes += bytes;
                    }
                }
                Home::Staged(_) => {
                    // tile handoff inside the staging region: no DMA
                }
                Home::Dram => {
                    let mut bytes = info.size_bytes();
                    let mut reuse = false;
                    if let Some(tag) = nest.tile {
                        match nest_tensor_box(&prog.graph, nest, t) {
                            None => {
                                bytes = 0;
                                reuse = true;
                            }
                            Some((bbox, by)) => {
                                bytes = by;
                                let key = (tag.group, t);
                                if let Some((pidx, pbox)) = last_box.get(&key) {
                                    if *pbox == bbox
                                        && (tag.index == *pidx || tag.index == *pidx + 1)
                                    {
                                        reuse = true;
                                    }
                                }
                                last_box.insert(key, (tag.index, bbox));
                            }
                        }
                    }
                    if !reuse {
                        traffic.add(staged_class, bytes);
                        off_in_bytes += bytes;
                        staging_deposit_bytes += bytes;
                    }
                }
            }
        }

        // ---- output ----
        let out = nest.store.tensor;
        let out_info = prog.graph.tensor(out);
        let out_resident = plan
            .window_at(out, pos)
            .expect("plan covers stored tensors")
            .home
            .on_chip();

        // ---- execute ----
        let elem = out_info.dtype.size_bytes();
        match &nest.body {
            Body::Copy { .. } => {
                let moved = nest.domain.cardinality() * elem;
                let is_remap = matches!(node.kind, OpKind::MemCopy);
                if out_resident {
                    traffic.add(
                        if is_remap {
                            TrafficClass::OnchipRemap
                        } else {
                            TrafficClass::OnchipCopy
                        },
                        moved,
                    );
                    on_bytes += moved;
                } else {
                    traffic.add(TrafficClass::Spill, moved);
                    off_out_bytes += moved;
                }
            }
            Body::Compute { .. } => {
                if !out_resident {
                    let bytes = if nest.tile.is_some() {
                        nest_tensor_bytes(&prog.graph, nest, out)
                    } else {
                        out_info.size_bytes()
                    };
                    traffic.add(TrafficClass::Spill, bytes);
                    off_out_bytes += bytes;
                }
            }
        }

        costs.push(NestCost {
            compute: engine::compute_seconds(cfg, nest, &node.kind),
            dma_in: engine::dma_seconds(cfg, off_in_bytes, true)
                + engine::dma_seconds(cfg, on_bytes, false),
            dma_out: engine::dma_seconds(cfg, off_out_bytes, true),
        });
    }

    // ---- latency: both models over the same per-nest costs ----
    let mut serial_seconds = 0.0f64;
    for c in &costs {
        serial_seconds += engine::step_seconds(c.compute, c.dma_in + c.dma_out);
    }
    let mut pipelined_seconds = 0.0f64;
    for run in tile_runs(prog) {
        if prog.nests[run.0].tile.is_some() {
            pipelined_seconds += engine::pipeline_seconds(&run_steps(prog, run, &costs));
        } else {
            let c = costs[run.0];
            pipelined_seconds += engine::step_seconds(c.compute, c.dma_in + c.dma_out);
        }
    }

    // ---- output write-back ----
    for out in prog.graph.outputs() {
        let bytes = prog.graph.tensor(out).size_bytes();
        traffic.add(TrafficClass::OutputStore, bytes);
        let dma = engine::dma_seconds(cfg, bytes, true);
        serial_seconds += dma;
        pipelined_seconds += dma;
    }

    CostBreakdown {
        traffic,
        staging_deposit_bytes,
        serial_seconds,
        pipelined_seconds,
        peak_scratchpad: plan.peak_scratchpad_bytes(),
    }
}

/// Compulsory DRAM bytes of a program — a sound lower bound no plan
/// can beat (the joint optimizer's branch-and-bound floor).
///
/// Every graph output pays one full write-back. For each *used*
/// input/weight: any plan's charges for the tensor cover every element
/// it actually reads (a resident window fetches it whole; streamed
/// reads are charged by clipped image boxes that contain the reads),
/// so the total is bounded below by any single reader's **exact** read
/// set. A reader's exact read set equals its clipped image box only
/// when the box has no holes — [`exact_reader_bytes`] certifies that
/// (single guard-free affine piece, every coefficient ±1, no domain
/// dim feeding two tensor dims) and returns `None` for anything
/// gap-leaving (strided slices, diagonal reads), which then
/// contributes nothing to the floor. The bound is the max over
/// certified readers, capped at the tensor size.
pub fn compulsory_offchip(prog: &Program) -> i64 {
    let mut total = 0i64;
    for t in prog.graph.tensors() {
        if !matches!(t.kind, TensorKind::Input | TensorKind::Weight) {
            continue;
        }
        let readers = prog.readers(t.id);
        if readers.is_empty() {
            continue;
        }
        let best = readers
            .iter()
            .filter_map(|&p| exact_reader_bytes(&prog.graph, &prog.nests[p], t.id))
            .max()
            .unwrap_or(0);
        total += best.min(t.size_bytes());
    }
    for out in prog.graph.outputs() {
        total += prog.graph.tensor(out).size_bytes();
    }
    total
}

/// The exact byte count of one nest's reads of `t`, when the clipped
/// image box provably has no holes: exactly one guard-free affine
/// piece whose components use only ±1 coefficients, each domain dim
/// contributing to at most one component (a box maps to a box, densely
/// — e.g. conv's `i + k − p`, matmul's projections). `None` when the
/// reads may undercover their bounding box (strides, div/mod, guards,
/// piecewise unions, repeated dims), in which case the box byte count
/// is not a valid lower bound on delivered bytes.
fn exact_reader_bytes(
    g: &crate::ir::graph::Graph,
    nest: &crate::ir::loopnest::LoopNest,
    t: TensorId,
) -> Option<i64> {
    let mut found: Option<&crate::ir::loopnest::Access> = None;
    for load in nest.body.loads() {
        for piece in &load.pieces {
            if piece.tensor != Some(t) {
                continue;
            }
            if found.is_some() {
                return None; // piecewise: union box may overcount
            }
            found = Some(piece);
        }
    }
    let piece = found?;
    if !piece.guards.is_empty() || !piece.map.is_affine() {
        return None;
    }
    let nd = piece.map.in_dims();
    let mut used = vec![false; nd];
    for e in piece.map.exprs() {
        let (coeffs, _c) = e.as_affine(nd)?;
        for (d, &c) in coeffs.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if c != 1 && c != -1 {
                return None; // strided: the image box has holes
            }
            if used[d] {
                return None; // diagonal: dims alias across components
            }
            used[d] = true;
        }
    }
    Some(nest_tensor_bytes(g, nest, t))
}

/// Multi-core prediction for a pipeline-sharded model: per-stage
/// traffic merged with the inter-core fabric bytes, plus the pipelined
/// multi-core latencies (steady-state interval = bottleneck stage +
/// its hand-off; fill/drain accounted by the engine recurrence).
///
/// Built by [`combine_sharded`] from per-stage inputs; the shard
/// replay path feeds the *simulated* per-stage numbers through the
/// same combiner, so the sharded calibration contract (byte-exact
/// traffic, bit-exact seconds) reduces to the per-stage invariant the
/// repo already holds.
#[derive(Clone, Debug)]
pub struct ShardedCost {
    /// Per-stage pipelined seconds (one entry per core).
    pub stage_seconds: Vec<f64>,
    /// Per-stage hand-off seconds over the fabric (last entry 0).
    pub transfer_seconds: Vec<f64>,
    /// Merged per-class traffic of every stage, plus `InterCore` bytes
    /// charged once per boundary a cut tensor crosses.
    pub traffic: TrafficCounters,
    /// Steady-state batch initiation interval (throughput =
    /// batch / interval once the pipe is full).
    pub interval_seconds: f64,
    /// One batch end-to-end through the pipe (fill latency).
    pub latency_seconds: f64,
    /// Worst per-core scratchpad high-water mark.
    pub peak_scratchpad: i64,
}

impl ShardedCost {
    pub fn offchip_total(&self) -> i64 {
        self.traffic.offchip_total()
    }

    pub fn intercore_total(&self) -> i64 {
        self.traffic.intercore_total()
    }

    /// Bit-exact equality — the bar the sharded replay is held to.
    pub fn bits_eq(&self, other: &ShardedCost) -> bool {
        self.traffic == other.traffic
            && self.peak_scratchpad == other.peak_scratchpad
            && self.stage_seconds.len() == other.stage_seconds.len()
            && self
                .stage_seconds
                .iter()
                .zip(&other.stage_seconds)
                .all(|(a, b)| a.to_bits() == b.to_bits())
            && self
                .transfer_seconds
                .iter()
                .zip(&other.transfer_seconds)
                .all(|(a, b)| a.to_bits() == b.to_bits())
            && self.interval_seconds.to_bits() == other.interval_seconds.to_bits()
            && self.latency_seconds.to_bits() == other.latency_seconds.to_bits()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("stages", Json::Int(self.stage_seconds.len() as i64)),
            (
                "stage_seconds",
                Json::Arr(self.stage_seconds.iter().map(|&s| Json::Num(s)).collect()),
            ),
            (
                "transfer_seconds",
                Json::Arr(self.transfer_seconds.iter().map(|&s| Json::Num(s)).collect()),
            ),
            ("offchip_total", Json::Int(self.offchip_total())),
            ("intercore_total", Json::Int(self.intercore_total())),
            ("interval_seconds", Json::Num(self.interval_seconds)),
            ("latency_seconds", Json::Num(self.latency_seconds)),
            ("peak_scratchpad", Json::Int(self.peak_scratchpad)),
        ])
    }
}

/// Combine per-stage `(pipelined seconds, traffic, peak)` triples and
/// the per-stage boundary-crossing byte counts (`transfer_bytes[s]` =
/// bytes every tensor alive across the cut after stage `s` ships over
/// the fabric; last entry 0) into the multi-core prediction.
///
/// This is the *single* combiner both the cost side and the
/// multi-engine replay use — identical floating-point operation order,
/// so equal per-stage inputs give bit-equal sharded outputs.
pub fn combine_sharded(
    stage_seconds: &[f64],
    stage_traffic: &[&TrafficCounters],
    stage_peaks: &[i64],
    transfer_bytes: &[i64],
    cfg: &AccelConfig,
) -> ShardedCost {
    assert_eq!(stage_seconds.len(), stage_traffic.len());
    assert_eq!(stage_seconds.len(), stage_peaks.len());
    assert_eq!(stage_seconds.len(), transfer_bytes.len());
    let mut traffic = TrafficCounters::new();
    for t in stage_traffic {
        traffic = traffic.merged(t);
    }
    let mut transfer_seconds = Vec::with_capacity(transfer_bytes.len());
    for &b in transfer_bytes {
        traffic.add(TrafficClass::InterCore, b);
        transfer_seconds.push(engine::intercore_seconds(cfg, b));
    }
    let interval_seconds = engine::multicore_interval(stage_seconds, &transfer_seconds);
    let latency_seconds =
        engine::multicore_pipeline_seconds(stage_seconds, &transfer_seconds, 1);
    ShardedCost {
        stage_seconds: stage_seconds.to_vec(),
        transfer_seconds,
        traffic,
        interval_seconds,
        latency_seconds,
        peak_scratchpad: stage_peaks.iter().copied().max().unwrap_or(0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{simulate_pipelined, simulate_planned};
    use crate::ir::builder::GraphBuilder;
    use crate::passes::manager::{AllocStage, PassManager, TileStage};

    fn chain() -> crate::ir::Graph {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[1, 4, 16, 16]);
        let w = b.weight("w", &[4, 4, 3, 3]);
        let c = b.conv2d("c", x, w, 1, 1);
        let n = b.batchnorm("bn", c);
        let r = b.relu("r", n);
        b.mark_output(r);
        b.finish()
    }

    #[test]
    fn matches_planned_replay_untiled() {
        let cfg = AccelConfig::tiny(8 * 1024);
        let pm = PassManager {
            alloc: Some(AllocStage::for_accel(cfg.clone())),
            ..Default::default()
        };
        let rep = pm.run(chain()).unwrap();
        let plan = rep.plan.as_ref().unwrap();
        let sim = simulate_planned(&rep.program, plan, &cfg, None).unwrap();
        let cost = evaluate(&rep.program, plan, &cfg);
        assert_eq!(cost.traffic, sim.traffic);
        assert_eq!(cost.offchip_total(), sim.offchip_total());
        assert_eq!(cost.staging_deposit_bytes, sim.staging_deposit_bytes);
        assert_eq!(cost.serial_seconds, sim.seconds);
        assert_eq!(cost.peak_scratchpad, sim.peak_scratchpad);
    }

    #[test]
    fn bits_eq_is_bitwise_on_seconds() {
        let cfg = AccelConfig::tiny(8 * 1024);
        let pm = PassManager {
            alloc: Some(AllocStage::for_accel(cfg.clone())),
            ..Default::default()
        };
        let rep = pm.run(chain()).unwrap();
        let plan = rep.plan.as_ref().unwrap();
        let a = evaluate(&rep.program, plan, &cfg);
        let b = evaluate(&rep.program, plan, &cfg);
        assert!(a.bits_eq(&b), "deterministic evaluate must be bit-stable");
        let mut flipped = a.clone();
        flipped.pipelined_seconds = f64::from_bits(flipped.pipelined_seconds.to_bits() ^ 1);
        assert!(!a.bits_eq(&flipped), "a single flipped mantissa bit must be caught");
        let mut bumped = a.clone();
        bumped.staging_deposit_bytes += 1;
        assert!(!a.bits_eq(&bumped));
    }

    #[test]
    fn matches_pipelined_replay_tiled() {
        let cfg = AccelConfig::tiny(4 * 1024);
        let pm = PassManager {
            tile: Some(TileStage::for_accel(cfg.clone())),
            alloc: Some(AllocStage::for_accel(cfg.clone())),
            ..Default::default()
        };
        let rep = pm.run(chain()).unwrap();
        let plan = rep.plan.as_ref().unwrap();
        let planned = simulate_planned(&rep.program, plan, &cfg, None).unwrap();
        let pipelined = simulate_pipelined(&rep.program, plan, &cfg, None).unwrap();
        let cost = evaluate(&rep.program, plan, &cfg);
        assert_eq!(cost.traffic, planned.traffic);
        assert_eq!(cost.serial_seconds, planned.seconds);
        assert_eq!(cost.pipelined_seconds, pipelined.seconds);
    }

    #[test]
    fn compulsory_is_a_floor() {
        let cfg = AccelConfig::tiny(4 * 1024);
        let pm = PassManager {
            tile: Some(TileStage::for_accel(cfg.clone())),
            alloc: Some(AllocStage::for_accel(cfg.clone())),
            ..Default::default()
        };
        let rep = pm.run(chain()).unwrap();
        let plan = rep.plan.as_ref().unwrap();
        let cost = evaluate(&rep.program, plan, &cfg);
        assert!(cost.offchip_total() >= compulsory_offchip(&rep.program));
    }
}
