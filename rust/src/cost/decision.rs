//! The whole-model decision vector.
//!
//! One point in the joint memory-decision space: everything the
//! pipeline is free to choose about how a model's memory is staged,
//! gathered into a single value the optimizer can enumerate, realize
//! and score. The pipeline's historical behavior is exactly
//! [`DecisionVector::baseline`] — the staged-greedy configuration —
//! which seeds every search so the joint result is never worse than
//! what the greedy passes produce on their own.

use crate::alloc::{AllocOpts, SpillFlavor};
use crate::tile::{FusePolicy, TileOpts};

/// The tiling coordinates of a decision vector.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TileDecision {
    /// Fraction of the scratchpad the double-buffered tile working set
    /// may use.
    pub budget_fraction: f64,
    /// Fusion grouping rule for chain detection.
    pub fuse: FusePolicy,
}

impl TileDecision {
    /// The tiling configuration this decision stands for, **on top
    /// of** `base`: only the search axes (budget fraction, fusion
    /// policy) are overridden — the caller's other tiling settings
    /// (`max_tiles`) pass through untouched.
    pub fn to_opts_on(self, base: TileOpts) -> TileOpts {
        TileOpts {
            budget_fraction: self.budget_fraction,
            fuse: self.fuse != FusePolicy::None,
            fuse_policy: self.fuse,
            ..base
        }
    }

    pub fn to_opts(self) -> TileOpts {
        self.to_opts_on(TileOpts::default())
    }

    /// The decision a caller's configured tile stage stands for — the
    /// search's seed.
    pub fn from_opts(opts: &TileOpts) -> TileDecision {
        TileDecision {
            budget_fraction: opts.budget_fraction,
            fuse: if opts.fuse { opts.fuse_policy } else { FusePolicy::None },
        }
    }
}

/// The allocation coordinates of a decision vector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AllocDecision {
    /// Scheduler lookahead (node- or tile-group-granular).
    pub lookahead: usize,
    /// Spill victim policy.
    pub spill: SpillFlavor,
}

impl AllocDecision {
    /// The planner configuration this decision stands for, **on top
    /// of** `base`: only the search axes (lookahead, spill flavor) are
    /// overridden — the caller's other planner settings
    /// (`require_fit`, `max_rounds`) pass through untouched.
    pub fn to_opts_on(self, base: AllocOpts) -> AllocOpts {
        AllocOpts {
            lookahead: self.lookahead,
            spill: self.spill,
            ..base
        }
    }

    pub fn to_opts(self) -> AllocOpts {
        self.to_opts_on(AllocOpts::default())
    }
}

/// One candidate configuration of every memory decision: schedule
/// order (via the scheduler lookahead), fusion grouping and per-group
/// tile sizes (via the tiling coordinates — grid sizes follow
/// deterministically from the budget and fusion policy), residency
/// homes (implied by what the realized plan can stage) and spill
/// choices (via the spill flavor).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DecisionVector {
    /// `None` = no tiling stage for this candidate.
    pub tile: Option<TileDecision>,
    pub alloc: AllocDecision,
}

impl DecisionVector {
    /// Today's staged-greedy pipeline: default tiling with elementwise
    /// fusion, default lookahead, furthest-gap spills. The search's
    /// seed — evaluated first, never discarded unless strictly beaten.
    pub fn baseline() -> DecisionVector {
        DecisionVector {
            tile: Some(TileDecision {
                budget_fraction: TileOpts::default().budget_fraction,
                fuse: FusePolicy::Elementwise,
            }),
            alloc: AllocDecision {
                lookahead: AllocOpts::default().lookahead,
                spill: SpillFlavor::FurthestGap,
            },
        }
    }

    /// Compact human-readable form for stats and logs.
    pub fn describe(&self) -> String {
        let tile = match self.tile {
            None => "untiled".to_string(),
            Some(t) => format!("{:?}@{:.2}", t.fuse, t.budget_fraction),
        };
        format!(
            "tile={tile} lookahead={} spill={:?}",
            self.alloc.lookahead, self.alloc.spill
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_matches_default_opts() {
        let dv = DecisionVector::baseline();
        let t = dv.tile.unwrap().to_opts();
        let d = TileOpts::default();
        assert_eq!(t.budget_fraction, d.budget_fraction);
        assert_eq!(t.fuse_policy, FusePolicy::Elementwise);
        assert!(t.fuse);
        let a = dv.alloc.to_opts();
        assert_eq!(a.lookahead, AllocOpts::default().lookahead);
        assert_eq!(a.spill, SpillFlavor::FurthestGap);
    }

    #[test]
    fn describe_is_stable() {
        let dv = DecisionVector::baseline();
        let s = dv.describe();
        assert!(s.contains("Elementwise"), "{s}");
        assert!(s.contains("FurthestGap"), "{s}");
    }
}
