//! Chrome trace-event JSON export.
//!
//! Builds the "catapult" JSON Array/Object format that
//! `chrome://tracing` and Perfetto load directly: `B`/`E` duration
//! pairs per (pid, tid), `C` counter samples, and `M` thread-name
//! metadata. Timestamps are microseconds. The builder guarantees the
//! exported `traceEvents` are sorted by timestamp with `E` ordered
//! before `B` at equal timestamps, so back-to-back spans never read as
//! overlapping and the begin/end nesting stays balanced per thread —
//! the property the golden test in `tests/obs_telemetry.rs` pins.

use crate::util::json::Json;
use std::cmp::Ordering;

/// Single-process traces: everything lives under this pid.
pub const PID: i64 = 1;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Phase {
    Meta,
    End,
    Begin,
    Counter,
}

impl Phase {
    fn label(self) -> &'static str {
        match self {
            Phase::Meta => "M",
            Phase::End => "E",
            Phase::Begin => "B",
            Phase::Counter => "C",
        }
    }

    /// Sort rank at equal timestamps: metadata first, then `E` before
    /// `B` (a span ending exactly where the next begins must close
    /// first), counters last.
    fn rank(self) -> u8 {
        match self {
            Phase::Meta => 0,
            Phase::End => 1,
            Phase::Begin => 2,
            Phase::Counter => 3,
        }
    }
}

#[derive(Clone, Debug)]
struct Event {
    name: String,
    phase: Phase,
    ts_us: f64,
    tid: i64,
    /// Optional `args` payload: one `(key, value)` pair.
    arg: Option<(&'static str, Json)>,
}

/// Incremental trace builder.
#[derive(Clone, Debug, Default)]
pub struct ChromeTrace {
    events: Vec<Event>,
}

impl ChromeTrace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Name a thread (rendered as a track label by the viewers).
    pub fn thread_name(&mut self, tid: i64, name: &str) {
        self.events.push(Event {
            name: "thread_name".to_string(),
            phase: Phase::Meta,
            ts_us: 0.0,
            tid,
            arg: Some(("name", Json::Str(name.to_string()))),
        });
    }

    /// A `[start_s, start_s + dur_s]` span on `tid` (seconds in, µs
    /// out). Zero- and negative-duration spans are dropped: they carry
    /// no timeline information and would break `E`-before-`B` ordering.
    pub fn span(&mut self, tid: i64, name: &str, start_s: f64, dur_s: f64) {
        if dur_s <= 0.0 || dur_s.is_nan() {
            return;
        }
        self.events.push(Event {
            name: name.to_string(),
            phase: Phase::Begin,
            ts_us: start_s * 1e6,
            tid,
            arg: None,
        });
        self.events.push(Event {
            name: name.to_string(),
            phase: Phase::End,
            ts_us: (start_s + dur_s) * 1e6,
            tid,
            arg: None,
        });
    }

    /// A counter sample (its own track in the viewers).
    pub fn counter(&mut self, name: &str, ts_s: f64, value: i64) {
        self.events.push(Event {
            name: name.to_string(),
            phase: Phase::Counter,
            ts_us: ts_s * 1e6,
            tid: 0,
            arg: Some(("value", Json::Int(value))),
        });
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Serialize to the `{"traceEvents": [...]}` object form.
    pub fn to_json(&self) -> Json {
        let mut order: Vec<&Event> = self.events.iter().collect();
        order.sort_by(|a, b| {
            a.ts_us
                .partial_cmp(&b.ts_us)
                .unwrap_or(Ordering::Equal)
                .then(a.phase.rank().cmp(&b.phase.rank()))
        });
        let items: Vec<Json> = order
            .iter()
            .map(|e| {
                let mut pairs = vec![
                    ("name", Json::Str(e.name.clone())),
                    ("ph", Json::Str(e.phase.label().to_string())),
                    ("ts", Json::Num(e.ts_us)),
                    ("pid", Json::Int(PID)),
                    ("tid", Json::Int(e.tid)),
                ];
                if let Some((k, v)) = &e.arg {
                    pairs.push(("args", Json::obj(vec![(k, v.clone())])));
                }
                Json::obj(pairs)
            })
            .collect();
        Json::obj(vec![
            ("traceEvents", Json::Arr(items)),
            ("displayTimeUnit", Json::Str("ms".to_string())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_sorted_and_balanced() {
        let mut ct = ChromeTrace::new();
        ct.thread_name(0, "compute");
        // inserted out of order; exporter must sort
        ct.span(0, "b", 2.0, 1.0);
        ct.span(0, "a", 0.0, 2.0); // ends exactly where b begins
        ct.counter("occ", 1.0, 42);
        let j = ct.to_json();
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 6);
        let mut last = f64::NEG_INFINITY;
        let mut depth = 0i64;
        for e in evs {
            let ts = e.get("ts").unwrap().as_f64().unwrap();
            assert!(ts >= last);
            last = ts;
            match e.get("ph").unwrap().as_str().unwrap() {
                "B" => depth += 1,
                "E" => {
                    depth -= 1;
                    assert!(depth >= 0, "E before matching B");
                }
                _ => {}
            }
        }
        assert_eq!(depth, 0);
        // the a/b handoff at ts == 2s: E(a) must precede B(b)
        let at2: Vec<&str> = evs
            .iter()
            .filter(|e| e.get("ts").unwrap().as_f64() == Some(2e6))
            .map(|e| e.get("ph").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(at2, vec!["E", "B"]);
    }

    #[test]
    fn zero_duration_spans_dropped() {
        let mut ct = ChromeTrace::new();
        ct.span(0, "nil", 1.0, 0.0);
        assert!(ct.is_empty());
    }
}
