//! Bounded log-bucketed histogram.
//!
//! 65 power-of-two buckets (bucket 0 holds the value 0, bucket *i*
//! holds `[2^(i-1), 2^i)`), each tracking a count **and** a sum, so
//! recording is O(1), memory is constant regardless of sample volume,
//! and quantile estimates return the *mean of the bucket at the rank*
//! — exact whenever every sample in that bucket is equal (the common
//! case for repeated latencies), and within the bucket's 2× width
//! otherwise. This replaces the unbounded `Vec<u64>` +
//! clone-and-sort-per-snapshot pattern in the serving metrics.

use crate::util::json::Json;

/// Bucket count: value 0, plus one bucket per bit position of u64.
pub const BUCKETS: usize = 65;

/// A log-bucketed histogram of `u64` samples.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    counts: [u64; BUCKETS],
    sums: [u128; BUCKETS],
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram {
            counts: [0; BUCKETS],
            sums: [0; BUCKETS],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bucket for `v`: 0 for 0, else `64 - leading_zeros` (so bucket
    /// *i* covers `[2^(i-1), 2^i)`).
    fn bucket_index(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            (64 - v.leading_zeros()) as usize
        }
    }

    /// Lower bound of bucket `b`.
    fn bucket_lo(b: usize) -> u64 {
        if b == 0 {
            0
        } else {
            1u64 << (b - 1)
        }
    }

    /// Inclusive upper bound of bucket `b`.
    fn bucket_hi(b: usize) -> u64 {
        if b == 0 {
            0
        } else if b >= 64 {
            u64::MAX
        } else {
            (1u64 << b) - 1
        }
    }

    /// O(1) record.
    pub fn record(&mut self, v: u64) {
        let b = Self::bucket_index(v);
        self.counts[b] += 1;
        self.sums[b] += v as u128;
        self.count += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact sum of all recorded samples.
    pub fn sum(&self) -> u128 {
        self.sum
    }

    pub fn min(&self) -> u64 {
        if self.is_empty() {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Exact mean (the sum is exact even though quantiles are bucketed).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Quantile estimate: walks cumulative bucket counts to the rank
    /// `(count - 1) * p` (the same index a sorted vector would use) and
    /// returns that bucket's mean.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((self.count - 1) as f64 * p.clamp(0.0, 1.0)) as u64;
        let mut cum = 0u64;
        for b in 0..BUCKETS {
            if self.counts[b] == 0 {
                continue;
            }
            cum += self.counts[b];
            if cum > rank {
                return (self.sums[b] / self.counts[b] as u128) as u64;
            }
        }
        self.max
    }

    /// Merge another histogram into this one (bucket-wise; exact).
    pub fn merge(&mut self, other: &LogHistogram) {
        for b in 0..BUCKETS {
            self.counts[b] += other.counts[b];
            self.sums[b] += other.sums[b];
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// JSON summary: totals, quantiles, and the non-empty buckets.
    pub fn to_json(&self) -> Json {
        let cap = |v: u128| v.min(i64::MAX as u128) as i64;
        let mut buckets = Vec::new();
        for b in 0..BUCKETS {
            if self.counts[b] == 0 {
                continue;
            }
            buckets.push(Json::obj(vec![
                ("lo", Json::Int(cap(Self::bucket_lo(b) as u128))),
                ("hi", Json::Int(cap(Self::bucket_hi(b) as u128))),
                ("count", Json::Int(self.counts[b] as i64)),
            ]));
        }
        Json::obj(vec![
            ("count", Json::Int(cap(self.count as u128))),
            ("sum", Json::Int(cap(self.sum))),
            ("min", Json::Int(cap(self.min() as u128))),
            ("max", Json::Int(cap(self.max as u128))),
            ("p50", Json::Int(cap(self.percentile(0.50) as u128))),
            ("p99", Json::Int(cap(self.percentile(0.99) as u128))),
            ("buckets", Json::Arr(buckets)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(LogHistogram::bucket_index(0), 0);
        assert_eq!(LogHistogram::bucket_index(1), 1);
        assert_eq!(LogHistogram::bucket_index(2), 2);
        assert_eq!(LogHistogram::bucket_index(3), 2);
        assert_eq!(LogHistogram::bucket_index(4), 3);
        assert_eq!(LogHistogram::bucket_index(u64::MAX), 64);
        for b in 0..BUCKETS {
            assert_eq!(LogHistogram::bucket_index(LogHistogram::bucket_lo(b)), b);
            assert_eq!(LogHistogram::bucket_index(LogHistogram::bucket_hi(b)), b);
        }
    }

    #[test]
    fn exact_when_buckets_distinct() {
        // samples in distinct buckets: quantiles are exact
        let mut h = LogHistogram::new();
        for v in [100u64, 200, 300] {
            h.record(v);
        }
        assert_eq!(h.count(), 3);
        assert_eq!(h.sum(), 600);
        assert!((h.mean() - 200.0).abs() < 1e-12);
        assert_eq!(h.percentile(0.0), 100);
        assert_eq!(h.percentile(0.5), 200);
        assert_eq!(h.percentile(0.99), 200); // rank 1, like a sorted vec
        assert_eq!(h.percentile(1.0), 300);
        assert_eq!(h.min(), 100);
        assert_eq!(h.max(), 300);
    }

    #[test]
    fn bounded_memory_under_sustained_load() {
        let mut h = LogHistogram::new();
        for i in 0..100_000u64 {
            h.record(1000 + (i % 7));
        }
        assert_eq!(h.count(), 100_000);
        // all samples share bucket [512, 1024): estimate is the bucket
        // mean, within the true range
        let p99 = h.percentile(0.99);
        assert!((1000..=1006).contains(&p99), "{p99}");
    }

    #[test]
    fn empty_and_extremes() {
        let h = LogHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.percentile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0);
        let mut h = LogHistogram::new();
        h.record(u64::MAX);
        h.record(0);
        assert_eq!(h.max(), u64::MAX);
        assert_eq!(h.min(), 0);
        assert_eq!(h.percentile(0.0), 0);
    }

    #[test]
    fn merge_matches_combined_stream() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut whole = LogHistogram::new();
        for v in 0..100u64 {
            if v % 2 == 0 {
                a.record(v * 3);
            } else {
                b.record(v * 3);
            }
            whole.record(v * 3);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.sum(), whole.sum());
        assert_eq!(a.percentile(0.5), whole.percentile(0.5));
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
    }

    #[test]
    fn json_summary_has_buckets() {
        let mut h = LogHistogram::new();
        h.record(5);
        h.record(6);
        h.record(900);
        let j = h.to_json();
        assert_eq!(j.get("count").and_then(|v| v.as_i64()), Some(3));
        assert_eq!(j.get("buckets").and_then(|v| v.as_arr()).map(|a| a.len()), Some(2));
    }
}
