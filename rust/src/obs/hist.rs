//! Bounded log-bucketed histogram.
//!
//! 65 power-of-two buckets (bucket 0 holds the value 0, bucket *i*
//! holds `[2^(i-1), 2^i)`), each tracking a count **and** a sum, so
//! recording is O(1), memory is constant regardless of sample volume,
//! and quantile estimates return the *mean of the bucket at the rank*
//! — exact whenever every sample in that bucket is equal (the common
//! case for repeated latencies), and within the bucket's 2× width
//! otherwise. This replaces the unbounded `Vec<u64>` +
//! clone-and-sort-per-snapshot pattern in the serving metrics.

use crate::util::json::Json;

/// Bucket count: value 0, plus one bucket per bit position of u64.
pub const BUCKETS: usize = 65;

/// A log-bucketed histogram of `u64` samples.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    counts: [u64; BUCKETS],
    sums: [u128; BUCKETS],
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram {
            counts: [0; BUCKETS],
            sums: [0; BUCKETS],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bucket for `v`: 0 for 0, else `64 - leading_zeros` (so bucket
    /// *i* covers `[2^(i-1), 2^i)`).
    fn bucket_index(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            (64 - v.leading_zeros()) as usize
        }
    }

    /// Lower bound of bucket `b`.
    fn bucket_lo(b: usize) -> u64 {
        if b == 0 {
            0
        } else {
            1u64 << (b - 1)
        }
    }

    /// Inclusive upper bound of bucket `b`.
    fn bucket_hi(b: usize) -> u64 {
        if b == 0 {
            0
        } else if b >= 64 {
            u64::MAX
        } else {
            (1u64 << b) - 1
        }
    }

    /// O(1) record.
    pub fn record(&mut self, v: u64) {
        let b = Self::bucket_index(v);
        self.counts[b] += 1;
        self.sums[b] += v as u128;
        self.count += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact sum of all recorded samples.
    pub fn sum(&self) -> u128 {
        self.sum
    }

    pub fn min(&self) -> u64 {
        if self.is_empty() {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Exact mean (the sum is exact even though quantiles are bucketed).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Estimate of the `i`-th order statistic (what `sorted[i]` would
    /// be): exact when the bucket holding rank `i` has one sample, and
    /// a linear ramp across the bucket's effective range otherwise.
    /// The range is clipped to the recorded global `[min, max]`, so
    /// single-bucket mass of equal samples collapses to the exact
    /// value.
    fn sample_estimate(&self, i: u64) -> f64 {
        let mut cum = 0u64;
        for b in 0..BUCKETS {
            if self.counts[b] == 0 {
                continue;
            }
            if i < cum + self.counts[b] {
                let n = self.counts[b];
                if n == 1 {
                    return self.sums[b] as f64;
                }
                let lo = Self::bucket_lo(b).max(self.min) as f64;
                let hi = Self::bucket_hi(b).min(self.max) as f64;
                let local = (i - cum) as f64 / (n - 1) as f64;
                return lo + (hi - lo) * local;
            }
            cum += self.counts[b];
        }
        self.max as f64
    }

    /// Quantile estimate with the sorted-sample convention: fractional
    /// rank `r = (count - 1) * p`, linearly interpolated between the
    /// order-statistic estimates at `floor(r)` and `ceil(r)`, each
    /// itself linearly interpolated within its bucket. Exact for any
    /// `p` when the mass at the rank sits in a single bucket of equal
    /// samples (e.g. repeated latencies), and within the bucket's 2×
    /// width otherwise.
    pub fn percentile_f64(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let r = (self.count - 1) as f64 * p.clamp(0.0, 1.0);
        let lo_i = r.floor() as u64;
        let hi_i = r.ceil() as u64;
        let lo_v = self.sample_estimate(lo_i);
        if hi_i == lo_i {
            return lo_v;
        }
        let hi_v = self.sample_estimate(hi_i);
        let frac = r - r.floor();
        lo_v + (hi_v - lo_v) * frac
    }

    /// [`Self::percentile_f64`] rounded to the nearest integer sample
    /// value.
    pub fn percentile(&self, p: f64) -> u64 {
        self.percentile_f64(p).round() as u64
    }

    /// Merge another histogram into this one (bucket-wise; exact).
    pub fn merge(&mut self, other: &LogHistogram) {
        for b in 0..BUCKETS {
            self.counts[b] += other.counts[b];
            self.sums[b] += other.sums[b];
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// JSON summary: totals, quantiles, and the non-empty buckets.
    pub fn to_json(&self) -> Json {
        let cap = |v: u128| v.min(i64::MAX as u128) as i64;
        let mut buckets = Vec::new();
        for b in 0..BUCKETS {
            if self.counts[b] == 0 {
                continue;
            }
            buckets.push(Json::obj(vec![
                ("lo", Json::Int(cap(Self::bucket_lo(b) as u128))),
                ("hi", Json::Int(cap(Self::bucket_hi(b) as u128))),
                ("count", Json::Int(self.counts[b] as i64)),
            ]));
        }
        Json::obj(vec![
            ("count", Json::Int(cap(self.count as u128))),
            ("sum", Json::Int(cap(self.sum))),
            ("min", Json::Int(cap(self.min() as u128))),
            ("max", Json::Int(cap(self.max as u128))),
            ("p50", Json::Int(cap(self.percentile(0.50) as u128))),
            ("p99", Json::Int(cap(self.percentile(0.99) as u128))),
            ("buckets", Json::Arr(buckets)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(LogHistogram::bucket_index(0), 0);
        assert_eq!(LogHistogram::bucket_index(1), 1);
        assert_eq!(LogHistogram::bucket_index(2), 2);
        assert_eq!(LogHistogram::bucket_index(3), 2);
        assert_eq!(LogHistogram::bucket_index(4), 3);
        assert_eq!(LogHistogram::bucket_index(u64::MAX), 64);
        for b in 0..BUCKETS {
            assert_eq!(LogHistogram::bucket_index(LogHistogram::bucket_lo(b)), b);
            assert_eq!(LogHistogram::bucket_index(LogHistogram::bucket_hi(b)), b);
        }
    }

    #[test]
    fn exact_when_buckets_distinct() {
        // samples in distinct buckets: quantiles are exact
        let mut h = LogHistogram::new();
        for v in [100u64, 200, 300] {
            h.record(v);
        }
        assert_eq!(h.count(), 3);
        assert_eq!(h.sum(), 600);
        assert!((h.mean() - 200.0).abs() < 1e-12);
        assert_eq!(h.percentile(0.0), 100);
        assert_eq!(h.percentile(0.5), 200);
        // fractional rank 1.98 interpolates between samples 200 and
        // 300, exactly like numpy's linear quantile on the sorted vec
        assert_eq!(h.percentile(0.99), 298);
        assert_eq!(h.percentile(1.0), 300);
        assert_eq!(h.min(), 100);
        assert_eq!(h.max(), 300);
    }

    #[test]
    fn bounded_memory_under_sustained_load() {
        let mut h = LogHistogram::new();
        for i in 0..100_000u64 {
            h.record(1000 + (i % 7));
        }
        assert_eq!(h.count(), 100_000);
        // all samples share bucket [512, 1024): estimate is the bucket
        // mean, within the true range
        let p99 = h.percentile(0.99);
        assert!((1000..=1006).contains(&p99), "{p99}");
    }

    #[test]
    fn empty_and_extremes() {
        let h = LogHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.percentile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0);
        let mut h = LogHistogram::new();
        h.record(u64::MAX);
        h.record(0);
        assert_eq!(h.max(), u64::MAX);
        assert_eq!(h.min(), 0);
        assert_eq!(h.percentile(0.0), 0);
    }

    #[test]
    fn merge_matches_combined_stream() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut whole = LogHistogram::new();
        for v in 0..100u64 {
            if v % 2 == 0 {
                a.record(v * 3);
            } else {
                b.record(v * 3);
            }
            whole.record(v * 3);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.sum(), whole.sum());
        assert_eq!(a.percentile(0.5), whole.percentile(0.5));
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
    }

    /// numpy-style linear quantile on the exact sorted samples.
    fn exact_quantile(sorted: &[u64], p: f64) -> f64 {
        let r = (sorted.len() - 1) as f64 * p;
        let lo = sorted[r.floor() as usize] as f64;
        let hi = sorted[r.ceil() as usize] as f64;
        lo + (hi - lo) * (r - r.floor())
    }

    #[test]
    fn interpolated_quantiles_track_exact_sorted_samples() {
        // known distributions: uniform ramp, repeated mass, geometric
        let distributions: Vec<Vec<u64>> = vec![
            (1..=1000u64).collect(),
            (0..5000u64).map(|i| 1000 + (i % 7)).collect(),
            (0..200u64).map(|i| 1u64 << (i % 20)).collect(),
            vec![42; 999],
        ];
        for samples in distributions {
            let mut h = LogHistogram::new();
            let mut sorted = samples.clone();
            for &v in &samples {
                h.record(v);
            }
            sorted.sort_unstable();
            for &p in &[0.0, 0.25, 0.5, 0.9, 0.99, 0.999, 1.0] {
                let exact = exact_quantile(&sorted, p);
                let est = h.percentile_f64(p);
                // the estimate must stay within the bucket holding the
                // rank: never off by more than 2x (one log2 bucket)
                assert!(
                    est <= exact * 2.0 + 1.0 && exact <= est * 2.0 + 1.0,
                    "p={p}: est {est} vs exact {exact}"
                );
            }
            // single-bucket mass of equal samples is exact at every p
            if sorted.first() == sorted.last() {
                for &p in &[0.0, 0.5, 0.99, 1.0] {
                    assert_eq!(h.percentile_f64(p), sorted[0] as f64);
                }
            }
        }
        // exact case the issue calls out: every sample in its own
        // bucket means p50/p99 match the sorted vector to the sample
        let mut h = LogHistogram::new();
        let vals = [1u64, 2, 4, 8, 16, 32, 64, 128, 256, 512];
        for &v in &vals {
            h.record(v);
        }
        for &p in &[0.0, 0.5, 0.99, 1.0] {
            let exact = exact_quantile(&vals, p);
            assert!((h.percentile_f64(p) - exact).abs() < 1e-9, "p={p}");
        }
    }

    #[test]
    fn json_summary_has_buckets() {
        let mut h = LogHistogram::new();
        h.record(5);
        h.record(6);
        h.record(900);
        let j = h.to_json();
        assert_eq!(j.get("count").and_then(|v| v.as_i64()), Some(3));
        assert_eq!(j.get("buckets").and_then(|v| v.as_arr()).map(|a| a.len()), Some(2));
    }
}
