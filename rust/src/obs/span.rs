//! Request-scoped span tracing: a bounded, lock-light flight recorder.
//!
//! Every request accepted by the serving path gets a process-unique
//! **span id**; each stage of its life records one [`SpanEvent`]
//! (phase + start/end timestamps in nanoseconds) into a
//! [`FlightRecorder`] — a fixed-capacity ring buffer that overwrites
//! its oldest events under sustained load, so tracing is *always on*
//! without unbounded memory. The recorder is time-base agnostic: the
//! live `coordinator::Server` stamps events with wall-clock nanoseconds
//! since the recorder's epoch ([`FlightRecorder::now_ns`]), while
//! `serve::loadsim` stamps them with its virtual (u64 ns) clock, so the
//! same conservation checks and Chrome export work on both.
//!
//! **Span taxonomy** (one complete chain per accepted request; see
//! DESIGN.md §Observability):
//!
//! | phase          | interval                                  |
//! |----------------|-------------------------------------------|
//! | `Submit`       | submit() entry → request accepted         |
//! | `Enqueue`      | accepted → drained into a flush           |
//! | `BucketChoice` | instant at flush; `value` = chosen bucket |
//! | `Flush`        | flush decision → backend execution start  |
//! | `Replay`       | backend execution (predicted service time)|
//! | `Respond`      | execution end → response delivered        |
//!
//! **Conservation identity:** every accepted request yields exactly one
//! event per phase, with monotone timestamps — no orphan and no
//! duplicate spans. `tests/obs_serving.rs` and the coordinator stress
//! test pin this; [`FlightRecorder::chains`] is the shared checker.
//!
//! Export: [`FlightRecorder::to_chrome`] lays the chains out on a
//! minimal set of lanes (greedy interval assignment, so concurrent
//! requests never overlap on one track) and emits the bucket choices
//! as a counter track — loadable directly in `chrome://tracing` /
//! Perfetto via `Server::trace_chrome_json` or
//! `simulate --serve-trace-out`.

use super::chrome::ChromeTrace;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Stages of one request's life through the serving path.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanPhase {
    /// `submit()` entry until the request is accepted.
    Submit,
    /// Accepted until drained into a flush (queue wait).
    Enqueue,
    /// Instant of the flush's bucket decision; `value` is the bucket.
    BucketChoice,
    /// Flush decision until backend execution starts.
    Flush,
    /// Backend execution (the bucket's predicted service replay).
    Replay,
    /// Execution end until the response is delivered.
    Respond,
}

impl SpanPhase {
    pub fn name(self) -> &'static str {
        match self {
            SpanPhase::Submit => "submit",
            SpanPhase::Enqueue => "enqueue",
            SpanPhase::BucketChoice => "bucket_choice",
            SpanPhase::Flush => "flush",
            SpanPhase::Replay => "replay",
            SpanPhase::Respond => "respond",
        }
    }

    /// Every phase of a complete chain, in chain order.
    pub fn all() -> [SpanPhase; 6] {
        [
            SpanPhase::Submit,
            SpanPhase::Enqueue,
            SpanPhase::BucketChoice,
            SpanPhase::Flush,
            SpanPhase::Replay,
            SpanPhase::Respond,
        ]
    }
}

/// One recorded phase of one request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanEvent {
    /// The request's process-unique span id.
    pub span: u64,
    pub phase: SpanPhase,
    /// Start, nanoseconds since the recorder's time base.
    pub start_ns: u64,
    /// End, nanoseconds; equal to `start_ns` for instant phases.
    pub end_ns: u64,
    /// Phase payload: the chosen bucket for `BucketChoice`, the batch
    /// size for `Flush`/`Replay`, 0 otherwise.
    pub value: i64,
}

/// One request's reassembled chain (see [`FlightRecorder::chains`]).
#[derive(Clone, Debug, Default)]
pub struct SpanChain {
    /// Events in phase order (complete chains have one per phase).
    pub events: Vec<SpanEvent>,
}

impl SpanChain {
    /// A chain is complete when it has exactly one event per phase and
    /// the phase intervals are monotone (each starts no earlier than
    /// the previous ends, instants included).
    pub fn is_complete(&self) -> bool {
        let order = SpanPhase::all();
        if self.events.len() != order.len() {
            return false;
        }
        for (ev, want) in self.events.iter().zip(order.iter()) {
            if ev.phase != *want || ev.end_ns < ev.start_ns {
                return false;
            }
        }
        self.events
            .windows(2)
            .all(|w| w[1].start_ns >= w[0].start_ns && w[1].end_ns >= w[0].end_ns)
    }
}

/// Fixed-capacity ring of span events. Recording takes one short
/// mutex hold (push or overwrite, O(1)); span ids and the overwrite
/// counter are plain atomics, so the request path never blocks on the
/// exporter for long.
pub struct FlightRecorder {
    epoch: Instant,
    next_span: AtomicU64,
    overwritten: AtomicU64,
    ring: Mutex<Ring>,
}

struct Ring {
    buf: Vec<SpanEvent>,
    /// Next overwrite position once `buf` has reached capacity.
    head: usize,
    cap: usize,
}

/// Default event capacity of a server's always-on recorder: bounds
/// memory at roughly `DEFAULT_CAPACITY × size_of::<SpanEvent>()`
/// (~0.75 MiB) no matter how long the server runs.
pub const DEFAULT_CAPACITY: usize = 16 * 1024;

impl FlightRecorder {
    /// A recorder holding at most `capacity` events (min 1).
    pub fn new(capacity: usize) -> FlightRecorder {
        FlightRecorder {
            epoch: Instant::now(),
            next_span: AtomicU64::new(0),
            overwritten: AtomicU64::new(0),
            ring: Mutex::new(Ring { buf: Vec::new(), head: 0, cap: capacity.max(1) }),
        }
    }

    /// Nanoseconds since this recorder was created (the wall-clock
    /// time base; virtual-time users stamp events themselves).
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Allocate the next span id (1-based, process-unique per
    /// recorder).
    pub fn next_span_id(&self) -> u64 {
        self.next_span.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Span ids handed out so far.
    pub fn spans_started(&self) -> u64 {
        self.next_span.load(Ordering::Relaxed)
    }

    /// Events evicted to keep the ring within capacity.
    pub fn overwritten(&self) -> u64 {
        self.overwritten.load(Ordering::Relaxed)
    }

    pub fn capacity(&self) -> usize {
        self.ring.lock().unwrap().cap
    }

    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Record one event (O(1); evicts the oldest event when full).
    pub fn record(&self, ev: SpanEvent) {
        let mut g = self.ring.lock().unwrap();
        if g.buf.len() < g.cap {
            g.buf.push(ev);
        } else {
            let h = g.head;
            g.buf[h] = ev;
            g.head = (h + 1) % g.cap;
            self.overwritten.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Convenience: record a `[start, end]` phase of `span`.
    pub fn record_phase(&self, span: u64, phase: SpanPhase, start_ns: u64, end_ns: u64, value: i64) {
        self.record(SpanEvent { span, phase, start_ns, end_ns: end_ns.max(start_ns), value });
    }

    /// Every retained event, oldest first.
    pub fn snapshot(&self) -> Vec<SpanEvent> {
        let g = self.ring.lock().unwrap();
        let mut out = Vec::with_capacity(g.buf.len());
        out.extend_from_slice(&g.buf[g.head..]);
        out.extend_from_slice(&g.buf[..g.head]);
        out
    }

    /// Retained events reassembled per span, each chain sorted into
    /// phase order (ties by start time). Complete chains satisfy
    /// [`SpanChain::is_complete`].
    pub fn chains(&self) -> BTreeMap<u64, SpanChain> {
        let mut map: BTreeMap<u64, SpanChain> = BTreeMap::new();
        for ev in self.snapshot() {
            map.entry(ev.span).or_default().events.push(ev);
        }
        for chain in map.values_mut() {
            chain.events.sort_by_key(|e| (e.phase, e.start_ns));
        }
        map
    }

    /// Export the retained chains as a Chrome trace. Chains are packed
    /// onto the fewest lanes such that concurrent requests never share
    /// one (greedy interval assignment in arrival order); the bucket
    /// choices become a `bucket` counter track.
    pub fn to_chrome(&self) -> ChromeTrace {
        let chains = self.chains();
        // chain interval = [first event start, last event end]
        let mut intervals: Vec<(u64, u64, &SpanChain)> = chains
            .values()
            .filter(|c| !c.events.is_empty())
            .map(|c| {
                let lo = c.events.iter().map(|e| e.start_ns).min().unwrap_or(0);
                let hi = c.events.iter().map(|e| e.end_ns).max().unwrap_or(0);
                (lo, hi, c)
            })
            .collect();
        intervals.sort_by_key(|&(lo, hi, _)| (lo, hi));
        let mut ct = ChromeTrace::new();
        let mut lane_free_at: Vec<u64> = Vec::new();
        for (lo, hi, chain) in intervals {
            let lane = match lane_free_at.iter().position(|&free| free <= lo) {
                Some(l) => l,
                None => {
                    lane_free_at.push(0);
                    ct.thread_name(lane_free_at.len() as i64 - 1, &format!(
                        "req-lane-{}",
                        lane_free_at.len() - 1
                    ));
                    lane_free_at.len() - 1
                }
            };
            lane_free_at[lane] = hi.max(lo + 1);
            for ev in &chain.events {
                let start_s = ev.start_ns as f64 / 1e9;
                let dur_s = (ev.end_ns - ev.start_ns) as f64 / 1e9;
                ct.span(lane as i64, ev.phase.name(), start_s, dur_s);
                if ev.phase == SpanPhase::BucketChoice {
                    ct.counter("bucket", start_s, ev.value);
                }
            }
        }
        ct
    }
}

impl Default for FlightRecorder {
    fn default() -> Self {
        FlightRecorder::new(DEFAULT_CAPACITY)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain_events(span: u64, t0: u64, bucket: i64) -> Vec<SpanEvent> {
        let p = SpanPhase::all();
        vec![
            SpanEvent { span, phase: p[0], start_ns: t0, end_ns: t0 + 10, value: 0 },
            SpanEvent { span, phase: p[1], start_ns: t0 + 10, end_ns: t0 + 100, value: 0 },
            SpanEvent { span, phase: p[2], start_ns: t0 + 100, end_ns: t0 + 100, value: bucket },
            SpanEvent { span, phase: p[3], start_ns: t0 + 100, end_ns: t0 + 110, value: bucket },
            SpanEvent { span, phase: p[4], start_ns: t0 + 110, end_ns: t0 + 500, value: bucket },
            SpanEvent { span, phase: p[5], start_ns: t0 + 500, end_ns: t0 + 510, value: 0 },
        ]
    }

    #[test]
    fn span_ids_are_unique_and_dense() {
        let fr = FlightRecorder::new(8);
        assert_eq!(fr.next_span_id(), 1);
        assert_eq!(fr.next_span_id(), 2);
        assert_eq!(fr.spans_started(), 2);
    }

    #[test]
    fn ring_overwrites_oldest_beyond_capacity() {
        let fr = FlightRecorder::new(4);
        for k in 0..10u64 {
            fr.record(SpanEvent {
                span: k,
                phase: SpanPhase::Submit,
                start_ns: k,
                end_ns: k + 1,
                value: 0,
            });
        }
        assert_eq!(fr.len(), 4);
        assert_eq!(fr.overwritten(), 6);
        let spans: Vec<u64> = fr.snapshot().iter().map(|e| e.span).collect();
        assert_eq!(spans, vec![6, 7, 8, 9], "oldest events must go first");
    }

    #[test]
    fn chains_reassemble_and_complete() {
        let fr = FlightRecorder::new(64);
        // interleave two chains out of order
        let a = chain_events(1, 0, 4);
        let b = chain_events(2, 50, 8);
        for k in 0..a.len() {
            fr.record(b[k]);
            fr.record(a[a.len() - 1 - k]);
        }
        let chains = fr.chains();
        assert_eq!(chains.len(), 2);
        for (span, chain) in &chains {
            assert!(chain.is_complete(), "span {span} incomplete: {chain:?}");
        }
        // dropping one phase breaks completeness
        let fr2 = FlightRecorder::new(64);
        for ev in a.iter().skip(1) {
            fr2.record(*ev);
        }
        assert!(!fr2.chains()[&1].is_complete());
        // a duplicated phase breaks completeness too
        let fr3 = FlightRecorder::new(64);
        for ev in &a {
            fr3.record(*ev);
        }
        fr3.record(a[2]);
        assert!(!fr3.chains()[&1].is_complete());
    }

    #[test]
    fn chrome_export_is_balanced_and_laned() {
        let fr = FlightRecorder::new(64);
        // two overlapping chains -> two lanes; one later chain reuses
        // lane 0
        for ev in chain_events(1, 0, 4) {
            fr.record(ev);
        }
        for ev in chain_events(2, 100, 8) {
            fr.record(ev);
        }
        for ev in chain_events(3, 10_000, 2) {
            fr.record(ev);
        }
        let j = fr.to_chrome().to_json();
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        // per-tid B/E balance
        let mut depth: BTreeMap<i64, i64> = BTreeMap::new();
        let mut last_ts = f64::NEG_INFINITY;
        let mut lanes: std::collections::BTreeSet<i64> = Default::default();
        for e in evs {
            let ts = e.get("ts").unwrap().as_f64().unwrap();
            assert!(ts >= last_ts, "unsorted trace");
            last_ts = ts;
            let tid = e.get("tid").unwrap().as_i64().unwrap();
            match e.get("ph").unwrap().as_str().unwrap() {
                "B" => {
                    *depth.entry(tid).or_insert(0) += 1;
                    lanes.insert(tid);
                }
                "E" => {
                    let d = depth.entry(tid).or_insert(0);
                    *d -= 1;
                    assert!(*d >= 0, "E before B on lane {tid}");
                }
                _ => {}
            }
        }
        assert!(depth.values().all(|&d| d == 0), "unbalanced lanes: {depth:?}");
        assert_eq!(lanes.len(), 2, "expected exactly 2 lanes, got {lanes:?}");
        // the bucket decisions surface as a counter track
        assert!(evs.iter().any(|e| {
            e.get("ph").unwrap().as_str() == Some("C")
                && e.get("name").unwrap().as_str() == Some("bucket")
        }));
    }

    #[test]
    fn record_phase_clamps_backwards_intervals() {
        let fr = FlightRecorder::new(4);
        fr.record_phase(1, SpanPhase::Replay, 100, 50, 0);
        let ev = fr.snapshot()[0];
        assert_eq!(ev.start_ns, 100);
        assert_eq!(ev.end_ns, 100, "end must be clamped to start");
    }
}
