//! One shared plain-text metric encoder.
//!
//! `coordinator::metrics::Snapshot::render_text` (Prometheus
//! exposition) and [`super::ObsSnapshot::render_text`] (the keyed
//! human-readable dump) used to hand-roll their line formats
//! separately; both are now expressed on this encoder so the framing
//! (one metric per line, trailing newline, `name{label="v"} value`
//! label syntax) lives in exactly one place. Output is byte-for-byte
//! what the hand-rolled versions produced — tests pin it.

use std::fmt;
use std::fmt::Write as _;

/// Line-oriented metric text builder.
#[derive(Default)]
pub struct TextEncoder {
    buf: String,
}

impl TextEncoder {
    pub fn new() -> TextEncoder {
        TextEncoder::default()
    }

    /// Prometheus unlabelled sample: `name value`.
    pub fn metric(&mut self, name: &str, value: impl fmt::Display) {
        let _ = writeln!(self.buf, "{name} {value}");
    }

    /// Prometheus sample with one label pair: `name{label="lv"} value`.
    pub fn metric_with(
        &mut self,
        name: &str,
        label: &str,
        label_value: impl fmt::Display,
        value: impl fmt::Display,
    ) {
        let _ = writeln!(self.buf, "{name}{{{label}=\"{label_value}\"}} {value}");
    }

    /// Keyed human-readable line: `kind name rest` (the obs snapshot
    /// dump format).
    pub fn keyed(&mut self, kind: &str, name: &str, rest: impl fmt::Display) {
        let _ = writeln!(self.buf, "{kind} {name} {rest}");
    }

    pub fn finish(self) -> String {
        self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_match_the_hand_rolled_formats() {
        let mut e = TextEncoder::new();
        e.metric("polymem_requests_total", 2u64);
        e.metric("polymem_batch_size_mean", format_args!("{:.3}", 1.5f64));
        e.metric_with("polymem_request_latency_us", "quantile", 0.5f64, 200u128);
        e.keyed("counter", "bytes", 15i64);
        e.keyed("phase", "work", format_args!("{:.6}s", 0.25f64));
        assert_eq!(
            e.finish(),
            "polymem_requests_total 2\n\
             polymem_batch_size_mean 1.500\n\
             polymem_request_latency_us{quantile=\"0.5\"} 200\n\
             counter bytes 15\n\
             phase work 0.250000s\n"
        );
    }
}
