//! Zero-dependency telemetry: counters, histograms, phase timings.
//!
//! Three pieces, shared by the simulator, the pass pipeline and the
//! serving coordinator:
//!
//! * [`hist::LogHistogram`] — bounded log-bucket histogram (O(1)
//!   record, constant memory, quantiles from buckets);
//! * [`chrome::ChromeTrace`] — Chrome trace-event / Perfetto JSON
//!   export for engine timelines (`simulate --trace-out`);
//! * [`Collector`] — a thread-safe sink of named counters, histograms
//!   and phase timings, with a process-global instance behind an
//!   on/off gate.
//!
//! **Zero-overhead-when-disabled contract:** the free functions
//! ([`add`], [`observe`], [`phase`]) check one relaxed atomic load and
//! return immediately unless [`set_enabled`]`(true)` was called. Hot
//! paths (the opt beam loop, the replay inner loops) may therefore be
//! instrumented unconditionally; the cost when disabled is a
//! predictable branch, which is what keeps `bench_opt` candidate
//! throughput within noise of the uninstrumented build.

pub mod chrome;
pub mod hist;
pub mod span;
pub mod text;

pub use chrome::ChromeTrace;
pub use hist::LogHistogram;
pub use span::{FlightRecorder, SpanChain, SpanEvent, SpanPhase};
pub use text::TextEncoder;

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is global telemetry collection on? (Off by default.)
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn global telemetry collection on or off.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// One timed phase (a compiler pass, a search stage).
#[derive(Clone, Debug, PartialEq)]
pub struct PhaseSample {
    pub name: String,
    pub seconds: f64,
}

impl PhaseSample {
    pub fn new(name: &str, seconds: f64) -> Self {
        PhaseSample { name: name.to_string(), seconds }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("seconds", Json::Num(self.seconds)),
        ])
    }
}

/// Everything a [`Collector`] has accumulated.
#[derive(Clone, Debug, Default)]
pub struct ObsSnapshot {
    pub counters: BTreeMap<String, i64>,
    pub histograms: BTreeMap<String, LogHistogram>,
    pub phases: Vec<PhaseSample>,
}

impl ObsSnapshot {
    pub fn to_json(&self) -> Json {
        let counters = self
            .counters
            .iter()
            .map(|(k, v)| (k.clone(), Json::Int(*v)))
            .collect();
        let histograms = self
            .histograms
            .iter()
            .map(|(k, h)| (k.clone(), h.to_json()))
            .collect();
        Json::obj(vec![
            ("counters", Json::Obj(counters)),
            ("histograms", Json::Obj(histograms)),
            ("phases", Json::Arr(self.phases.iter().map(|p| p.to_json()).collect())),
        ])
    }

    /// Deterministic plain-text rendering (one metric per line),
    /// framed by the shared [`TextEncoder`].
    pub fn render_text(&self) -> String {
        let mut enc = TextEncoder::new();
        for (k, v) in &self.counters {
            enc.keyed("counter", k, v);
        }
        for (k, h) in &self.histograms {
            enc.keyed(
                "hist",
                k,
                format_args!(
                    "count={} sum={} min={} p50={} p99={} max={}",
                    h.count(),
                    h.sum(),
                    h.min(),
                    h.percentile(0.50),
                    h.percentile(0.99),
                    h.max()
                ),
            );
        }
        for p in &self.phases {
            enc.keyed("phase", &p.name, format_args!("{:.6}s", p.seconds));
        }
        enc.finish()
    }
}

/// Thread-safe telemetry sink. Local instances are cheap; the
/// process-global one is reached through [`global`] (or the gated free
/// functions).
pub struct Collector {
    inner: Mutex<Option<ObsSnapshot>>,
}

impl Default for Collector {
    fn default() -> Self {
        Collector { inner: Mutex::new(None) }
    }
}

impl Collector {
    pub fn new() -> Self {
        Self::default()
    }

    fn with<T>(&self, f: impl FnOnce(&mut ObsSnapshot) -> T) -> T {
        let mut guard = self.inner.lock().unwrap();
        f(guard.get_or_insert_with(ObsSnapshot::default))
    }

    /// Increment a named counter.
    pub fn add(&self, name: &str, delta: i64) {
        self.with(|s| *s.counters.entry(name.to_string()).or_insert(0) += delta);
    }

    /// Record a sample into a named histogram.
    pub fn observe(&self, name: &str, value: u64) {
        self.with(|s| s.histograms.entry(name.to_string()).or_default().record(value));
    }

    /// Append a timed phase.
    pub fn phase(&self, name: &str, seconds: f64) {
        self.with(|s| s.phases.push(PhaseSample::new(name, seconds)));
    }

    /// Time `f` and record it as a phase.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.phase(name, t0.elapsed().as_secs_f64());
        out
    }

    /// Merge a whole snapshot into this collector: counters add,
    /// histograms merge bucket-wise, phases append. This is how the
    /// joint search folds per-worker telemetry into the global
    /// collector in one locked step — workers record into plain
    /// [`ObsSnapshot`]s (or [`crate::opt`]'s pool reports) off to the
    /// side instead of contending on the global mutex per sample.
    pub fn absorb(&self, other: &ObsSnapshot) {
        self.with(|s| {
            for (k, v) in &other.counters {
                *s.counters.entry(k.clone()).or_insert(0) += v;
            }
            for (k, h) in &other.histograms {
                s.histograms.entry(k.clone()).or_default().merge(h);
            }
            s.phases.extend(other.phases.iter().cloned());
        });
    }

    pub fn snapshot(&self) -> ObsSnapshot {
        self.inner.lock().unwrap().clone().unwrap_or_default()
    }

    pub fn reset(&self) {
        *self.inner.lock().unwrap() = None;
    }
}

/// The process-global collector. Always usable; the gated free
/// functions below are the zero-overhead way to reach it from hot
/// paths.
pub fn global() -> &'static Collector {
    // `Option<ObsSnapshot>` makes the initializer const-evaluable, so
    // no lazy-init primitive is needed for the static.
    static GLOBAL: Collector = Collector { inner: Mutex::new(None) };
    &GLOBAL
}

/// Gated counter increment on the global collector: a single relaxed
/// atomic load when telemetry is disabled.
pub fn add(name: &str, delta: i64) {
    if enabled() {
        global().add(name, delta);
    }
}

/// Gated histogram sample on the global collector.
pub fn observe(name: &str, value: u64) {
    if enabled() {
        global().observe(name, value);
    }
}

/// Gated phase record on the global collector.
pub fn phase(name: &str, seconds: f64) {
    if enabled() {
        global().phase(name, seconds);
    }
}

/// Serializes tests that toggle the global gate or reset the global
/// collector (the test harness runs same-binary tests concurrently).
#[cfg(test)]
pub(crate) static TEST_GATE: Mutex<()> = Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collector_accumulates() {
        let c = Collector::new();
        c.add("bytes", 10);
        c.add("bytes", 5);
        c.observe("lat", 100);
        c.observe("lat", 300);
        let v = c.time("work", || 42);
        assert_eq!(v, 42);
        let s = c.snapshot();
        assert_eq!(s.counters.get("bytes"), Some(&15));
        assert_eq!(s.histograms.get("lat").map(|h| h.count()), Some(2));
        assert_eq!(s.phases.len(), 1);
        assert_eq!(s.phases[0].name, "work");
        assert!(s.phases[0].seconds >= 0.0);
        let text = s.render_text();
        assert!(text.contains("counter bytes 15"));
        assert!(text.contains("hist lat count=2"));
        let j = s.to_json();
        assert_eq!(
            j.get("counters").and_then(|c| c.get("bytes")).and_then(|v| v.as_i64()),
            Some(10 + 5)
        );
        c.reset();
        assert!(c.snapshot().counters.is_empty());
    }

    #[test]
    fn absorb_merges_counters_histograms_and_phases() {
        let worker_a = {
            let c = Collector::new();
            c.add("pool.jobs", 3);
            c.observe("pool.lat", 10);
            c.phase("pool.busy", 0.25);
            c.snapshot()
        };
        let worker_b = {
            let c = Collector::new();
            c.add("pool.jobs", 4);
            c.observe("pool.lat", 30);
            c.phase("pool.busy", 0.5);
            c.snapshot()
        };
        let sink = Collector::new();
        sink.add("pool.jobs", 1); // pre-existing counts accumulate, not overwrite
        sink.absorb(&worker_a);
        sink.absorb(&worker_b);
        let s = sink.snapshot();
        assert_eq!(s.counters.get("pool.jobs"), Some(&8));
        assert_eq!(s.histograms.get("pool.lat").map(|h| h.count()), Some(2));
        assert_eq!(s.histograms.get("pool.lat").map(|h| h.sum()), Some(40));
        assert_eq!(s.phases.len(), 2);
        assert!(s.phases.iter().all(|p| p.name == "pool.busy"));
    }

    #[test]
    fn gated_helpers_noop_when_disabled() {
        let _g = TEST_GATE.lock().unwrap();
        // default-off: writes through the free functions must not land
        set_enabled(false);
        let before = global().snapshot().counters.get("obs.test.gated").copied();
        add("obs.test.gated", 1);
        let after = global().snapshot().counters.get("obs.test.gated").copied();
        assert_eq!(before, after);
        assert!(!enabled());
    }

    #[test]
    fn gated_helpers_record_when_enabled() {
        let _g = TEST_GATE.lock().unwrap();
        set_enabled(true);
        add("obs.test.enabled", 2);
        observe("obs.test.hist", 7);
        phase("obs.test.phase", 0.5);
        set_enabled(false);
        let s = global().snapshot();
        assert!(s.counters.get("obs.test.enabled").copied().unwrap_or(0) >= 2);
        assert!(s.histograms.get("obs.test.hist").map(|h| h.count()).unwrap_or(0) >= 1);
        assert!(s.phases.iter().any(|p| p.name == "obs.test.phase"));
    }
}
