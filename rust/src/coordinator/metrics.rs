//! Serving metrics: request counters, latency distribution, batch-size
//! histogram. Lock-protected aggregate — the request path touches it
//! once per request, which criterion-level benches show is ≪1µs.
//!
//! Distributions are [`LogHistogram`]s: constant memory no matter how
//! long the server runs (the previous per-sample `Vec<u64>` grew
//! without bound), O(1) record, and quantiles answered from bucket
//! means — exact whenever the observed values land in distinct
//! buckets, within a factor of 2 otherwise.

use crate::obs::LogHistogram;
use std::sync::Mutex;
use std::time::Duration;

#[derive(Debug, Default, Clone)]
struct Inner {
    requests: u64,
    batches: u64,
    errors: u64,
    latency_us: LogHistogram,
    batch_sizes: LogHistogram,
    /// Cost-model-predicted off-chip DRAM bytes of every executed
    /// batch (cost-aware bucketized flushes only; 0 for fixed-policy
    /// backends with no bucket table).
    predicted_offchip_bytes: i64,
}

/// Thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// Snapshot with derived statistics.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub requests: u64,
    pub batches: u64,
    pub errors: u64,
    pub mean_latency: Duration,
    pub p50_latency: Duration,
    pub p99_latency: Duration,
    pub mean_batch: f64,
    /// Predicted off-chip bytes accumulated across executed batches
    /// (cost-aware bucketized serving only).
    pub predicted_offchip_bytes: i64,
    /// The full request-latency distribution (microseconds).
    pub latency: LogHistogram,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, batch_size: usize, latencies: &[Duration]) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.requests += batch_size as u64;
        g.batch_sizes.record(batch_size as u64);
        for l in latencies {
            g.latency_us.record(l.as_micros() as u64);
        }
    }

    pub fn record_error(&self, batch_size: usize) {
        let mut g = self.inner.lock().unwrap();
        g.errors += batch_size as u64;
    }

    /// Account one executed batch's predicted off-chip traffic (the
    /// chosen bucket's `cost::evaluate` bytes).
    pub fn record_offchip(&self, bytes: i64) {
        let mut g = self.inner.lock().unwrap();
        g.predicted_offchip_bytes += bytes.max(0);
    }

    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        let lat = &g.latency_us;
        let mean = if lat.is_empty() {
            Duration::ZERO
        } else {
            Duration::from_micros((lat.sum() / lat.count() as u128) as u64)
        };
        let mean_batch = if g.batch_sizes.is_empty() {
            0.0
        } else {
            g.batch_sizes.mean()
        };
        Snapshot {
            requests: g.requests,
            batches: g.batches,
            errors: g.errors,
            mean_latency: mean,
            p50_latency: Duration::from_micros(lat.percentile(0.50)),
            p99_latency: Duration::from_micros(lat.percentile(0.99)),
            mean_batch,
            predicted_offchip_bytes: g.predicted_offchip_bytes,
            latency: lat.clone(),
        }
    }
}

impl Snapshot {
    /// Prometheus-style plain-text rendering (the coordinator's
    /// `metrics_text` endpoint).
    pub fn render_text(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("polymem_requests_total {}\n", self.requests));
        s.push_str(&format!("polymem_batches_total {}\n", self.batches));
        s.push_str(&format!("polymem_errors_total {}\n", self.errors));
        s.push_str(&format!("polymem_batch_size_mean {:.3}\n", self.mean_batch));
        s.push_str(&format!(
            "polymem_predicted_offchip_bytes_total {}\n",
            self.predicted_offchip_bytes
        ));
        s.push_str(&format!(
            "polymem_request_latency_us_count {}\n",
            self.latency.count()
        ));
        s.push_str(&format!(
            "polymem_request_latency_us_sum {}\n",
            self.latency.sum()
        ));
        for (q, v) in [
            (0.50, self.p50_latency),
            (0.99, self.p99_latency),
        ] {
            s.push_str(&format!(
                "polymem_request_latency_us{{quantile=\"{q}\"}} {}\n",
                v.as_micros()
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let m = Metrics::new();
        m.record_batch(2, &[Duration::from_micros(100), Duration::from_micros(300)]);
        m.record_batch(1, &[Duration::from_micros(200)]);
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.batches, 2);
        assert_eq!(s.mean_latency, Duration::from_micros(200));
        assert_eq!(s.p50_latency, Duration::from_micros(200));
        assert!((s.mean_batch - 1.5).abs() < 1e-9);
    }

    #[test]
    fn empty_snapshot() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.mean_latency, Duration::ZERO);
    }

    #[test]
    fn errors_counted() {
        let m = Metrics::new();
        m.record_error(4);
        assert_eq!(m.snapshot().errors, 4);
    }

    #[test]
    fn offchip_bytes_accumulate() {
        let m = Metrics::new();
        m.record_offchip(1000);
        m.record_offchip(500);
        let s = m.snapshot();
        assert_eq!(s.predicted_offchip_bytes, 1500);
        assert!(s.render_text().contains("polymem_predicted_offchip_bytes_total 1500"));
    }

    #[test]
    fn memory_bounded_under_sustained_load() {
        // the sink must not grow with request count: a week of traffic
        // is the same size as one batch
        let m = Metrics::new();
        for k in 0..100_000u64 {
            m.record_batch(4, &[Duration::from_micros(50 + k % 1000)]);
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 400_000);
        assert_eq!(s.latency.count(), 100_000);
        assert!(s.p50_latency <= s.p99_latency);
        // LogHistogram is a fixed-size value type — snapshotting it
        // proves the inner state is constant-size too
        assert!(std::mem::size_of_val(&s.latency) < 64 * 1024);
    }

    #[test]
    fn render_text_is_prometheus_shaped() {
        let m = Metrics::new();
        m.record_batch(2, &[Duration::from_micros(100), Duration::from_micros(300)]);
        let text = m.snapshot().render_text();
        assert!(text.contains("polymem_requests_total 2"));
        assert!(text.contains("polymem_request_latency_us_count 2"));
        assert!(text.contains("quantile=\"0.5\""));
        assert!(text.contains("quantile=\"0.99\""));
        let empty = Metrics::new().snapshot().render_text();
        assert!(empty.contains("polymem_requests_total 0"));
    }
}
