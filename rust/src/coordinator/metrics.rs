//! Serving metrics: request counters, latency distribution, batch-size
//! histogram. Lock-protected aggregate — the request path touches it
//! once per request, which criterion-level benches show is ≪1µs.

use std::sync::Mutex;
use std::time::Duration;

#[derive(Debug, Default, Clone)]
struct Inner {
    requests: u64,
    batches: u64,
    errors: u64,
    latencies_us: Vec<u64>,
    batch_sizes: Vec<usize>,
}

/// Thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// Snapshot with derived statistics.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub requests: u64,
    pub batches: u64,
    pub errors: u64,
    pub mean_latency: Duration,
    pub p50_latency: Duration,
    pub p99_latency: Duration,
    pub mean_batch: f64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, batch_size: usize, latencies: &[Duration]) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.requests += batch_size as u64;
        g.batch_sizes.push(batch_size);
        for l in latencies {
            g.latencies_us.push(l.as_micros() as u64);
        }
    }

    pub fn record_error(&self, batch_size: usize) {
        let mut g = self.inner.lock().unwrap();
        g.errors += batch_size as u64;
    }

    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        let mut lat = g.latencies_us.clone();
        lat.sort_unstable();
        let pct = |p: f64| -> Duration {
            if lat.is_empty() {
                return Duration::ZERO;
            }
            Duration::from_micros(lat[((lat.len() - 1) as f64 * p) as usize])
        };
        let mean = if lat.is_empty() {
            Duration::ZERO
        } else {
            Duration::from_micros(lat.iter().sum::<u64>() / lat.len() as u64)
        };
        let mean_batch = if g.batch_sizes.is_empty() {
            0.0
        } else {
            g.batch_sizes.iter().sum::<usize>() as f64 / g.batch_sizes.len() as f64
        };
        Snapshot {
            requests: g.requests,
            batches: g.batches,
            errors: g.errors,
            mean_latency: mean,
            p50_latency: pct(0.50),
            p99_latency: pct(0.99),
            mean_batch,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let m = Metrics::new();
        m.record_batch(2, &[Duration::from_micros(100), Duration::from_micros(300)]);
        m.record_batch(1, &[Duration::from_micros(200)]);
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.batches, 2);
        assert_eq!(s.mean_latency, Duration::from_micros(200));
        assert_eq!(s.p50_latency, Duration::from_micros(200));
        assert!((s.mean_batch - 1.5).abs() < 1e-9);
    }

    #[test]
    fn empty_snapshot() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.mean_latency, Duration::ZERO);
    }

    #[test]
    fn errors_counted() {
        let m = Metrics::new();
        m.record_error(4);
        assert_eq!(m.snapshot().errors, 4);
    }
}
