//! Serving metrics: request counters, latency distribution, batch-size
//! histogram. Lock-protected aggregate — the request path touches it
//! once per request, which criterion-level benches show is ≪1µs.
//!
//! Distributions are [`LogHistogram`]s: constant memory no matter how
//! long the server runs (the previous per-sample `Vec<u64>` grew
//! without bound), O(1) record, and quantiles answered from bucket
//! means — exact whenever the observed values land in distinct
//! buckets, within a factor of 2 otherwise.

use crate::obs::{LogHistogram, TextEncoder};
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

/// Per-bucket predicted-vs-actual accounting for the cost-drift
/// auditor: what the plan cache's bucket table promised for every
/// flush executed at this bucket, against what the backend measured.
/// For `serve::PlannedBackend` both drifts are exactly zero (the
/// service-time contract); any other value means a backend diverged
/// from its published cost table.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BucketDrift {
    /// Batches executed at this bucket.
    pub batches: u64,
    /// Sum of the bucket table's predicted off-chip bytes.
    pub predicted_bytes: i64,
    /// Sum of the backend-measured off-chip bytes.
    pub actual_bytes: i64,
    /// Sum of the bucket table's predicted service seconds.
    pub predicted_seconds: f64,
    /// Sum of the backend-measured service seconds.
    pub actual_seconds: f64,
}

impl BucketDrift {
    /// Actual minus predicted off-chip bytes (0 = byte-exact).
    pub fn bytes_drift(&self) -> i64 {
        self.actual_bytes - self.predicted_bytes
    }

    /// Actual minus predicted service seconds (0.0 = bit-exact).
    pub fn seconds_drift(&self) -> f64 {
        self.actual_seconds - self.predicted_seconds
    }
}

#[derive(Debug, Default, Clone)]
struct Inner {
    requests: u64,
    batches: u64,
    errors: u64,
    latency_us: LogHistogram,
    batch_sizes: LogHistogram,
    /// Cost-model-predicted off-chip DRAM bytes of every executed
    /// batch (cost-aware bucketized flushes only; 0 for fixed-policy
    /// backends with no bucket table).
    predicted_offchip_bytes: i64,
    /// Cost-drift audit, keyed by bucket batch size.
    drift: BTreeMap<usize, BucketDrift>,
    /// Plan-cache buckets evicted by the LRU cap (reported by the
    /// serving layer from `PlanCache::evictions`).
    plan_cache_evictions: u64,
}

/// Thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// Snapshot with derived statistics.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub requests: u64,
    pub batches: u64,
    pub errors: u64,
    pub mean_latency: Duration,
    pub p50_latency: Duration,
    pub p99_latency: Duration,
    pub mean_batch: f64,
    /// Predicted off-chip bytes accumulated across executed batches
    /// (cost-aware bucketized serving only).
    pub predicted_offchip_bytes: i64,
    /// The full request-latency distribution (microseconds).
    pub latency: LogHistogram,
    /// Per-bucket cost-drift audit (empty until a backend reports
    /// actuals).
    pub drift: BTreeMap<usize, BucketDrift>,
    /// Plan-cache buckets evicted by the LRU cap.
    pub plan_cache_evictions: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, batch_size: usize, latencies: &[Duration]) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.requests += batch_size as u64;
        g.batch_sizes.record(batch_size as u64);
        for l in latencies {
            g.latency_us.record(l.as_micros() as u64);
        }
    }

    pub fn record_error(&self, batch_size: usize) {
        let mut g = self.inner.lock().unwrap();
        g.errors += batch_size as u64;
    }

    /// Account one executed batch's predicted off-chip traffic (the
    /// chosen bucket's `cost::evaluate` bytes).
    pub fn record_offchip(&self, bytes: i64) {
        let mut g = self.inner.lock().unwrap();
        g.predicted_offchip_bytes += bytes.max(0);
    }

    /// Audit one executed batch: the bucket table's prediction against
    /// what the backend measured.
    pub fn record_drift(
        &self,
        bucket: usize,
        predicted_bytes: i64,
        actual_bytes: i64,
        predicted_seconds: f64,
        actual_seconds: f64,
    ) {
        let mut g = self.inner.lock().unwrap();
        let d = g.drift.entry(bucket).or_default();
        d.batches += 1;
        d.predicted_bytes += predicted_bytes;
        d.actual_bytes += actual_bytes;
        d.predicted_seconds += predicted_seconds;
        d.actual_seconds += actual_seconds;
    }

    /// Publish the plan cache's running LRU eviction total (a monotone
    /// counter owned by the cache; the sink keeps the latest value).
    pub fn set_plan_cache_evictions(&self, total: u64) {
        let mut g = self.inner.lock().unwrap();
        g.plan_cache_evictions = g.plan_cache_evictions.max(total);
    }

    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        let lat = &g.latency_us;
        let mean = if lat.is_empty() {
            Duration::ZERO
        } else {
            Duration::from_micros((lat.sum() / lat.count() as u128) as u64)
        };
        let mean_batch = if g.batch_sizes.is_empty() {
            0.0
        } else {
            g.batch_sizes.mean()
        };
        Snapshot {
            requests: g.requests,
            batches: g.batches,
            errors: g.errors,
            mean_latency: mean,
            p50_latency: Duration::from_micros(lat.percentile(0.50)),
            p99_latency: Duration::from_micros(lat.percentile(0.99)),
            mean_batch,
            predicted_offchip_bytes: g.predicted_offchip_bytes,
            latency: lat.clone(),
            drift: g.drift.clone(),
            plan_cache_evictions: g.plan_cache_evictions,
        }
    }
}

impl Snapshot {
    /// Prometheus-style plain-text rendering (the coordinator's
    /// `metrics_text` endpoint), framed by the shared
    /// [`TextEncoder`]. Metric-naming convention: `polymem_*_total`
    /// for monotone counters, `polymem_*_us` + `quantile` label for
    /// latency summaries, `polymem_cost_drift_*` + `bucket` label for
    /// the drift gauges (see DESIGN.md §Observability).
    pub fn render_text(&self) -> String {
        let mut enc = TextEncoder::new();
        enc.metric("polymem_requests_total", self.requests);
        enc.metric("polymem_batches_total", self.batches);
        enc.metric("polymem_errors_total", self.errors);
        enc.metric("polymem_batch_size_mean", format_args!("{:.3}", self.mean_batch));
        enc.metric(
            "polymem_predicted_offchip_bytes_total",
            self.predicted_offchip_bytes,
        );
        enc.metric("polymem_plan_cache_evictions_total", self.plan_cache_evictions);
        enc.metric("polymem_request_latency_us_count", self.latency.count());
        enc.metric("polymem_request_latency_us_sum", self.latency.sum());
        for (q, v) in [
            (0.50, self.p50_latency),
            (0.99, self.p99_latency),
        ] {
            enc.metric_with("polymem_request_latency_us", "quantile", q, v.as_micros());
        }
        for (bucket, d) in &self.drift {
            enc.metric_with("polymem_bucket_batches_total", "bucket", bucket, d.batches);
            enc.metric_with("polymem_cost_drift_bytes", "bucket", bucket, d.bytes_drift());
            enc.metric_with(
                "polymem_cost_drift_seconds",
                "bucket",
                bucket,
                d.seconds_drift(),
            );
        }
        enc.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_cache_evictions_render_and_never_regress() {
        let m = Metrics::new();
        assert!(m
            .snapshot()
            .render_text()
            .contains("polymem_plan_cache_evictions_total 0"));
        m.set_plan_cache_evictions(3);
        m.set_plan_cache_evictions(2); // stale republish must not rewind
        assert_eq!(m.snapshot().plan_cache_evictions, 3);
        assert!(m
            .snapshot()
            .render_text()
            .contains("polymem_plan_cache_evictions_total 3"));
    }

    #[test]
    fn aggregates() {
        let m = Metrics::new();
        m.record_batch(2, &[Duration::from_micros(100), Duration::from_micros(300)]);
        m.record_batch(1, &[Duration::from_micros(200)]);
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.batches, 2);
        assert_eq!(s.mean_latency, Duration::from_micros(200));
        assert_eq!(s.p50_latency, Duration::from_micros(200));
        assert!((s.mean_batch - 1.5).abs() < 1e-9);
    }

    #[test]
    fn empty_snapshot() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.mean_latency, Duration::ZERO);
    }

    #[test]
    fn errors_counted() {
        let m = Metrics::new();
        m.record_error(4);
        assert_eq!(m.snapshot().errors, 4);
    }

    #[test]
    fn offchip_bytes_accumulate() {
        let m = Metrics::new();
        m.record_offchip(1000);
        m.record_offchip(500);
        let s = m.snapshot();
        assert_eq!(s.predicted_offchip_bytes, 1500);
        assert!(s.render_text().contains("polymem_predicted_offchip_bytes_total 1500"));
    }

    #[test]
    fn drift_audit_accumulates_per_bucket() {
        let m = Metrics::new();
        // bucket 4: prediction held exactly (the planned-backend case)
        m.record_drift(4, 1000, 1000, 0.25, 0.25);
        m.record_drift(4, 1000, 1000, 0.25, 0.25);
        // bucket 8: a backend that diverged from its published table
        m.record_drift(8, 2000, 2600, 0.5, 0.75);
        let s = m.snapshot();
        let d4 = s.drift.get(&4).unwrap();
        assert_eq!(d4.batches, 2);
        assert_eq!(d4.bytes_drift(), 0);
        assert_eq!(d4.seconds_drift(), 0.0);
        let d8 = s.drift.get(&8).unwrap();
        assert_eq!(d8.bytes_drift(), 600);
        assert!((d8.seconds_drift() - 0.25).abs() < 1e-12);
        let text = s.render_text();
        assert!(text.contains("polymem_bucket_batches_total{bucket=\"4\"} 2"), "{text}");
        assert!(text.contains("polymem_cost_drift_bytes{bucket=\"4\"} 0"), "{text}");
        assert!(text.contains("polymem_cost_drift_seconds{bucket=\"4\"} 0"), "{text}");
        assert!(text.contains("polymem_cost_drift_bytes{bucket=\"8\"} 600"), "{text}");
    }

    #[test]
    fn memory_bounded_under_sustained_load() {
        // the sink must not grow with request count: a week of traffic
        // is the same size as one batch
        let m = Metrics::new();
        for k in 0..100_000u64 {
            m.record_batch(4, &[Duration::from_micros(50 + k % 1000)]);
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 400_000);
        assert_eq!(s.latency.count(), 100_000);
        assert!(s.p50_latency <= s.p99_latency);
        // LogHistogram is a fixed-size value type — snapshotting it
        // proves the inner state is constant-size too
        assert!(std::mem::size_of_val(&s.latency) < 64 * 1024);
    }

    #[test]
    fn render_text_is_prometheus_shaped() {
        let m = Metrics::new();
        m.record_batch(2, &[Duration::from_micros(100), Duration::from_micros(300)]);
        let text = m.snapshot().render_text();
        assert!(text.contains("polymem_requests_total 2"));
        assert!(text.contains("polymem_request_latency_us_count 2"));
        assert!(text.contains("quantile=\"0.5\""));
        assert!(text.contains("quantile=\"0.99\""));
        let empty = Metrics::new().snapshot().render_text();
        assert!(empty.contains("polymem_requests_total 0"));
    }
}
