//! Execution backends for the serving coordinator.

use super::batcher::BucketCost;
use crate::runtime::LoadedModel;
use crate::util::error::Result;

/// Measured actuals of one executed batch, reported by backends that
/// can attribute their own memory traffic and service time (the
/// plan-replay `serve::PlannedBackend`). The server's cost-drift
/// auditor compares these against the bucket table's predictions per
/// flush — for planned backends the two must agree byte- and
/// bit-exactly (the plan cache's service-time contract, made
/// observable).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BatchActuals {
    /// The bucket (compiled batch size) that actually executed.
    pub bucket_batch: usize,
    /// Off-chip DRAM bytes of the execution, from the pipelined replay.
    pub offchip_bytes: i64,
    /// Service seconds of the execution, from the pipelined replay.
    pub service_seconds: f64,
}

/// Executes a batch of same-shaped requests. The coordinator owns
/// exactly one backend per worker thread. Backends need not be `Send`
/// (PJRT executables are not): [`crate::coordinator::Server::start`]
/// takes a factory closure and constructs the backend *on* the worker
/// thread.
pub trait Backend: 'static {
    /// Flattened per-request input length.
    fn input_len(&self) -> usize;
    /// Flattened per-request output length.
    fn output_len(&self) -> usize;
    /// Largest batch the backend can execute at once.
    fn max_batch(&self) -> usize;
    /// Execute `n` requests packed row-major into `batch`
    /// (`n × input_len` elements); returns `n × output_len` elements.
    fn infer(&mut self, batch: &[f32], n: usize) -> Result<Vec<f32>>;

    /// Per-bucket predicted cost table for cost-aware batching.
    /// Backends serving a set of precompiled batch-size buckets (the
    /// plan cache's `serve::PlannedBackend`) return one entry per
    /// bucket; the server then sizes every flush by amortized off-chip
    /// bytes per request. The default `None` keeps the classic fixed
    /// `max_batch` flush policy.
    fn bucket_costs(&self) -> Option<Vec<BucketCost>> {
        None
    }

    /// Measured actuals of the most recent successful [`Self::infer`]
    /// call, for backends that can attribute them (plan-replay
    /// backends). The server feeds these to the per-bucket cost-drift
    /// auditor after every batch; the default `None` leaves the
    /// auditor silent.
    fn last_batch_actuals(&self) -> Option<BatchActuals> {
        None
    }
}

/// Test/bench backend: output = input scaled by a constant, with an
/// optional artificial latency to exercise batching behaviour.
pub struct EchoBackend {
    pub len: usize,
    pub max_batch: usize,
    pub scale: f32,
    pub delay: std::time::Duration,
}

impl EchoBackend {
    pub fn new(len: usize, max_batch: usize) -> Self {
        EchoBackend { len, max_batch, scale: 2.0, delay: std::time::Duration::ZERO }
    }
}

impl Backend for EchoBackend {
    fn input_len(&self) -> usize {
        self.len
    }

    fn output_len(&self) -> usize {
        self.len
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn infer(&mut self, batch: &[f32], n: usize) -> Result<Vec<f32>> {
        crate::ensure!(batch.len() == n * self.len, "bad batch packing");
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        Ok(batch.iter().map(|v| v * self.scale).collect())
    }
}

/// Production backend: a PJRT executable compiled for a fixed batch
/// size `compiled_batch`. Smaller batches are zero-padded (standard
/// static-shape serving practice).
pub struct PjrtBackend {
    model: LoadedModel,
    compiled_batch: usize,
    in_len: usize,
    out_len: usize,
    in_shape: Vec<i64>,
}

impl PjrtBackend {
    /// `in_shape` is the per-request input shape (without batch dim);
    /// `out_len` the per-request flattened output length.
    pub fn new(
        model: LoadedModel,
        compiled_batch: usize,
        in_shape: &[i64],
        out_len: usize,
    ) -> Self {
        let in_len: i64 = in_shape.iter().product();
        let mut full_shape = vec![compiled_batch as i64];
        full_shape.extend_from_slice(in_shape);
        PjrtBackend {
            model,
            compiled_batch,
            in_len: in_len as usize,
            out_len,
            in_shape: full_shape,
        }
    }
}

impl Backend for PjrtBackend {
    fn input_len(&self) -> usize {
        self.in_len
    }

    fn output_len(&self) -> usize {
        self.out_len
    }

    fn max_batch(&self) -> usize {
        self.compiled_batch
    }

    fn infer(&mut self, batch: &[f32], n: usize) -> Result<Vec<f32>> {
        crate::ensure!(n <= self.compiled_batch, "batch exceeds compiled size");
        crate::ensure!(batch.len() == n * self.in_len, "bad batch packing");
        // zero-pad to the compiled batch
        let mut padded = vec![0f32; self.compiled_batch * self.in_len];
        padded[..batch.len()].copy_from_slice(batch);
        let out = self.model.run_f32(&[(&padded, &self.in_shape)])?;
        crate::ensure!(
            out.len() >= n * self.out_len,
            "model returned {} elements, need {}",
            out.len(),
            n * self.out_len
        );
        Ok(out[..n * self.out_len].to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn echo_scales() {
        let mut b = EchoBackend::new(3, 8);
        let out = b.infer(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2).unwrap();
        assert_eq!(out, vec![2.0, 4.0, 6.0, 8.0, 10.0, 12.0]);
    }

    #[test]
    fn echo_rejects_bad_packing() {
        let mut b = EchoBackend::new(3, 8);
        assert!(b.infer(&[1.0, 2.0], 1).is_err());
    }

    #[test]
    #[cfg(feature = "pjrt")]
    fn pjrt_backend_pads_batches() {
        const HLO: &str = r#"
HloModule batch_double

ENTRY main {
  p0 = f32[4,2]{1,0} parameter(0)
  ROOT d = f32[4,2]{1,0} add(p0, p0)
}
"#;
        let rt = crate::runtime::RuntimeClient::cpu().unwrap();
        let model = rt.load_hlo_str("batch_double", HLO).unwrap();
        let mut b = PjrtBackend::new(model, 4, &[2], 2);
        // 2 live requests in a batch-4 executable
        let out = b.infer(&[1.0, 2.0, 3.0, 4.0], 2).unwrap();
        assert_eq!(out, vec![2.0, 4.0, 6.0, 8.0]);
        assert!(b.infer(&[0.0; 12], 6).is_err()); // over compiled batch
    }
}
