//! The serving loop: submission queue → batcher → backend worker.
//!
//! One worker thread owns the backend (PJRT executables are not Sync);
//! callers submit from any thread and block on (or poll) a per-request
//! response channel.
//!
//! Shutdown is deterministic: [`Server::shutdown`] (and `Drop`) takes
//! the submission sender out of its slot and drops it. The worker's
//! receiver then reports `Disconnected` — but only after every request
//! already sent has been pulled — so the worker drains and answers
//! everything that was accepted, then exits. There is no timeout
//! polling and no window in which an accepted request can be dropped:
//! `submit` holds the sender slot's lock across the send, so a request
//! either observes the sender gone (rejected with "server stopped",
//! its backpressure slot released) or lands in the channel before the
//! disconnect and is served.
//!
//! Flush sizing is cost-aware when the backend exposes a bucket table
//! ([`Backend::bucket_costs`], e.g. the plan-cache backed
//! `serve::PlannedBackend`): each flush serves the bucket minimizing
//! predicted off-chip bytes per request. Otherwise the classic fixed
//! `max_batch` policy applies.

use super::backend::Backend;
use super::batcher::{choose_bucket, BatchPolicy, Batcher, BucketCost, Flush};
use super::metrics::Metrics;
use crate::obs::span::{FlightRecorder, SpanPhase, DEFAULT_CAPACITY};
use crate::obs::TextEncoder;
use crate::util::error::Result;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Server configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Bound on queued requests (backpressure): submits fail fast
    /// beyond it.
    pub queue_cap: usize,
    /// Event capacity of the always-on span flight recorder (the
    /// oldest events are overwritten beyond it, so memory stays
    /// bounded no matter how long the server runs).
    pub span_cap: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_cap: 1024,
            span_cap: DEFAULT_CAPACITY,
        }
    }
}

struct Request {
    input: Vec<f32>,
    enqueued: Instant,
    /// Tracing span id (allocated at submit; threaded through the
    /// batcher so every flush can prove it served exactly these
    /// requests).
    span: u64,
    /// Recorder-clock acceptance time (start of the Enqueue phase).
    enqueued_ns: u64,
    respond: Sender<Result<Vec<f32>>>,
}

/// Handle to a response.
pub struct ResponseHandle {
    rx: Receiver<Result<Vec<f32>>>,
}

impl ResponseHandle {
    /// Block until the response arrives.
    pub fn wait(self) -> Result<Vec<f32>> {
        self.rx
            .recv()
            .map_err(|_| crate::format_err!("server dropped the request"))?
    }

    /// Non-blocking poll.
    pub fn try_take(&self) -> Option<Result<Vec<f32>>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                Some(Err(crate::format_err!("server dropped the request")))
            }
        }
    }
}

/// Batching inference server.
pub struct Server {
    /// Submission sender; `None` once shutdown has begun. Dropping it
    /// is the shutdown signal the worker observes as a disconnect.
    tx: Mutex<Option<Sender<Request>>>,
    queued: Arc<Mutex<usize>>,
    cfg: ServerConfig,
    metrics: Arc<Metrics>,
    recorder: Arc<FlightRecorder>,
    worker: Mutex<Option<std::thread::JoinHandle<()>>>,
    input_len: usize,
}

impl Server {
    /// Start the worker thread over a backend built by `factory` *on*
    /// the worker thread (PJRT executables are not `Send`, so they must
    /// be created where they run). The factory returns the backend plus
    /// its per-request input length.
    pub fn start_with<B, F>(factory: F, cfg: ServerConfig) -> Result<Server>
    where
        B: Backend,
        F: FnOnce() -> Result<B> + Send + 'static,
    {
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<Result<usize>>();
        let metrics = Arc::new(Metrics::new());
        let recorder = Arc::new(FlightRecorder::new(cfg.span_cap));
        let queued = Arc::new(Mutex::new(0usize));
        let worker = std::thread::Builder::new()
            .name("polymem-serve".into())
            .spawn({
                let metrics = metrics.clone();
                let recorder = recorder.clone();
                let queued = queued.clone();
                move || {
                    let backend = match factory() {
                        Ok(b) => {
                            let _ = ready_tx.send(Ok(b.input_len()));
                            b
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                    };
                    worker_loop(backend, cfg, rx, metrics, queued, recorder)
                }
            })
            .expect("spawning server worker");
        let input_len = ready_rx
            .recv()
            .map_err(|_| crate::format_err!("server worker died during startup"))??;
        Ok(Server {
            tx: Mutex::new(Some(tx)),
            queued,
            cfg,
            metrics,
            recorder,
            worker: Mutex::new(Some(worker)),
            input_len,
        })
    }

    /// Start over an already-constructed (Send) backend.
    pub fn start<B: Backend + Send>(backend: B, cfg: ServerConfig) -> Server {
        Server::start_with(move || Ok(backend), cfg).expect("infallible factory")
    }

    /// Submit one request. Fails fast when the queue is saturated
    /// (backpressure), the input length is wrong, or the server has
    /// stopped. A rejected submit never consumes a backpressure slot.
    pub fn submit(&self, input: Vec<f32>) -> Result<ResponseHandle> {
        let t_submit = self.recorder.now_ns();
        crate::ensure!(
            input.len() == self.input_len,
            "input length {} != expected {}",
            input.len(),
            self.input_len
        );
        {
            let mut q = self.queued.lock().unwrap();
            crate::ensure!(*q < self.cfg.queue_cap, "queue full ({} requests)", *q);
            *q += 1;
        }
        let (rtx, rrx) = channel();
        let span = self.recorder.next_span_id();
        // acceptance timestamp captured *before* the send: every
        // worker-side event of this span is then guaranteed to carry a
        // later timestamp, keeping the chain monotone
        let t_accept = self.recorder.now_ns();
        let req = Request {
            input,
            enqueued: Instant::now(),
            span,
            enqueued_ns: t_accept,
            respond: rtx,
        };
        // hold the sender slot across the send: a successful send is
        // then guaranteed to precede the shutdown disconnect, so every
        // accepted request is drained and answered
        let sent = match self.tx.lock().unwrap().as_ref() {
            Some(tx) => tx.send(req).is_ok(),
            None => false,
        };
        if !sent {
            // release the slot taken above — the request never reached
            // the worker (this used to leak, shrinking queue_cap
            // permanently). No span events were recorded for it, so
            // rejected submits leave no orphan chains.
            let mut q = self.queued.lock().unwrap();
            *q = q.saturating_sub(1);
            crate::bail!("server stopped");
        }
        self.recorder
            .record_phase(span, SpanPhase::Submit, t_submit, t_accept, 0);
        Ok(ResponseHandle { rx: rrx })
    }

    /// Requests currently holding a backpressure slot (submitted but
    /// not yet handed to the backend).
    pub fn queued(&self) -> usize {
        *self.queued.lock().unwrap()
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// The span flight recorder (request phases of recent traffic).
    pub fn recorder(&self) -> &FlightRecorder {
        &self.recorder
    }

    /// Prometheus-style plain-text rendering of the current metrics
    /// (what a scrape endpoint would serve): traffic counters, latency
    /// quantiles, per-bucket cost-drift gauges, and the flight
    /// recorder's own accounting.
    pub fn metrics_text(&self) -> String {
        let mut text = self.metrics.snapshot().render_text();
        let mut enc = TextEncoder::new();
        enc.metric("polymem_spans_started_total", self.recorder.spans_started());
        enc.metric("polymem_span_events", self.recorder.len());
        enc.metric(
            "polymem_span_events_overwritten_total",
            self.recorder.overwritten(),
        );
        text.push_str(&enc.finish());
        text
    }

    /// Chrome trace-event JSON of the retained request spans — load in
    /// `chrome://tracing` or Perfetto. One lane per concurrent
    /// request, plus a `bucket` counter track of flush decisions.
    pub fn trace_chrome_json(&self) -> String {
        self.recorder.to_chrome().to_json().to_string_pretty()
    }

    /// Stop accepting requests, drain everything already accepted, and
    /// wait for the worker to exit. Idempotent.
    pub fn shutdown(&self) {
        drop(self.tx.lock().unwrap().take());
        if let Some(w) = self.worker.lock().unwrap().take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop<B: Backend>(
    mut backend: B,
    cfg: ServerConfig,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
    queued: Arc<Mutex<usize>>,
    recorder: Arc<FlightRecorder>,
) {
    let max_batch = cfg.max_batch.min(backend.max_batch());
    let policy = BatchPolicy::new(max_batch.max(1), cfg.max_wait);
    let mut batcher = Batcher::new(policy);
    let mut pending: Vec<Request> = Vec::new();
    // cost-aware flush sizing when the backend publishes per-bucket
    // predicted costs (plan-cache backends); fixed max_batch otherwise
    let costs: Option<Vec<BucketCost>> = backend
        .bucket_costs()
        .map(|v| {
            v.into_iter()
                .filter(|c| c.batch >= 1 && c.batch <= policy.max_batch)
                .collect::<Vec<_>>()
        })
        .filter(|v| !v.is_empty());

    loop {
        // pull everything currently queued
        loop {
            match rx.try_recv() {
                Ok(req) => {
                    batcher.push(req.enqueued, req.span);
                    pending.push(req);
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    // shutdown: every accepted request is already in
                    // `pending` (the channel drained before the
                    // disconnect was reported) — answer them all
                    flush_all(
                        &mut backend,
                        &mut batcher,
                        &mut pending,
                        &metrics,
                        &queued,
                        costs.as_deref(),
                        &recorder,
                    );
                    return;
                }
            }
        }
        match batcher.poll(Instant::now()) {
            Flush::Now => {
                let (spans, chosen) = take_flush(&mut batcher, costs.as_deref(), &metrics);
                execute_batch(
                    &mut backend,
                    &mut pending,
                    &spans,
                    chosen,
                    &metrics,
                    &queued,
                    &recorder,
                );
            }
            Flush::Wait(d) => match rx.recv_timeout(d) {
                Ok(req) => {
                    batcher.push(req.enqueued, req.span);
                    pending.push(req);
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    flush_all(
                        &mut backend,
                        &mut batcher,
                        &mut pending,
                        &metrics,
                        &queued,
                        costs.as_deref(),
                        &recorder,
                    );
                    return;
                }
            },
            Flush::Empty => match rx.recv() {
                Ok(req) => {
                    batcher.push(req.enqueued, req.span);
                    pending.push(req);
                }
                // disconnected with nothing pending: clean exit
                Err(_) => return,
            },
        }
    }
}

/// Decide this flush's size: cost-aware bucket choice when a bucket
/// table is available (recording the bucket's predicted off-chip
/// traffic), the fixed `max_batch` policy otherwise. Returns the span
/// ids taken plus the chosen bucket's predicted cost (None under the
/// fixed policy), which the drift auditor compares against the
/// backend's measured actuals.
fn take_flush(
    batcher: &mut Batcher,
    costs: Option<&[BucketCost]>,
    metrics: &Metrics,
) -> (Vec<u64>, Option<BucketCost>) {
    match costs {
        Some(table) => match choose_bucket(batcher.pending(), table) {
            Some((take, bucket)) => {
                metrics.record_offchip(bucket.offchip_bytes);
                (batcher.take(take), Some(bucket))
            }
            None => (batcher.take_max(), None),
        },
        None => (batcher.take_max(), None),
    }
}

fn flush_all<B: Backend>(
    backend: &mut B,
    batcher: &mut Batcher,
    pending: &mut Vec<Request>,
    metrics: &Metrics,
    queued: &Mutex<usize>,
    costs: Option<&[BucketCost]>,
    recorder: &FlightRecorder,
) {
    while !pending.is_empty() {
        let (spans, chosen) = take_flush(batcher, costs, metrics);
        execute_batch(backend, pending, &spans, chosen, metrics, queued, recorder);
    }
}

fn execute_batch<B: Backend>(
    backend: &mut B,
    pending: &mut Vec<Request>,
    spans: &[u64],
    chosen: Option<BucketCost>,
    metrics: &Metrics,
    queued: &Mutex<usize>,
    recorder: &FlightRecorder,
) {
    let n = spans.len();
    if n == 0 {
        return;
    }
    let batch: Vec<Request> = pending.drain(..n).collect();
    // conservation between the batcher's accounting and the request
    // queue: a flush serves exactly the requests whose ids it took
    for (r, &s) in batch.iter().zip(spans) {
        assert_eq!(r.span, s, "batcher/queue span mismatch: flush would serve the wrong request");
    }
    {
        let mut q = queued.lock().unwrap();
        *q = q.saturating_sub(n);
    }
    let t_choice = recorder.now_ns();
    let bucket_value = chosen.map(|c| c.batch as i64).unwrap_or(n as i64);
    for r in &batch {
        recorder.record_phase(r.span, SpanPhase::Enqueue, r.enqueued_ns, t_choice, 0);
        recorder.record_phase(r.span, SpanPhase::BucketChoice, t_choice, t_choice, bucket_value);
    }
    let in_len = backend.input_len();
    let out_len = backend.output_len();
    let mut packed = Vec::with_capacity(n * in_len);
    for r in &batch {
        packed.extend_from_slice(&r.input);
    }
    let t_exec = recorder.now_ns();
    for r in &batch {
        recorder.record_phase(r.span, SpanPhase::Flush, t_choice, t_exec, n as i64);
    }
    match backend.infer(&packed, n) {
        Ok(out) => {
            let t_done = recorder.now_ns();
            for r in &batch {
                recorder.record_phase(r.span, SpanPhase::Replay, t_exec, t_done, n as i64);
            }
            // cost-drift audit: the bucket table's prediction for this
            // flush against what the backend measured
            if let (Some(pred), Some(act)) = (chosen, backend.last_batch_actuals()) {
                metrics.record_drift(
                    pred.batch,
                    pred.offchip_bytes,
                    act.offchip_bytes,
                    pred.service_seconds,
                    act.service_seconds,
                );
            }
            let now = Instant::now();
            let latencies: Vec<Duration> =
                batch.iter().map(|r| now.duration_since(r.enqueued)).collect();
            metrics.record_batch(n, &latencies);
            for (k, r) in batch.into_iter().enumerate() {
                let slice = out[k * out_len..(k + 1) * out_len].to_vec();
                // recorded before the send: once the caller unblocks,
                // its full chain is already in the recorder
                recorder.record_phase(r.span, SpanPhase::Respond, t_done, recorder.now_ns(), 0);
                let _ = r.respond.send(Ok(slice));
            }
        }
        Err(e) => {
            let t_done = recorder.now_ns();
            metrics.record_error(n);
            for r in batch {
                recorder.record_phase(r.span, SpanPhase::Replay, t_exec, t_done, n as i64);
                recorder.record_phase(r.span, SpanPhase::Respond, t_done, recorder.now_ns(), 0);
                let _ = r.respond.send(Err(crate::format_err!("inference failed: {e}")));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::EchoBackend;

    #[test]
    fn roundtrip_single() {
        let srv = Server::start(EchoBackend::new(3, 8), ServerConfig::default());
        let h = srv.submit(vec![1.0, 2.0, 3.0]).unwrap();
        assert_eq!(h.wait().unwrap(), vec![2.0, 4.0, 6.0]);
        let s = srv.metrics().snapshot();
        assert_eq!(s.requests, 1);
        srv.shutdown();
    }

    #[test]
    fn batches_form_under_load() {
        let cfg = ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(20),
            queue_cap: 1024,
            ..Default::default()
        };
        let mut be = EchoBackend::new(2, 8);
        be.delay = Duration::from_millis(2); // slow enough to queue up
        let srv = Server::start(be, cfg);
        let handles: Vec<_> = (0..64)
            .map(|k| srv.submit(vec![k as f32, 0.0]).unwrap())
            .collect();
        for (k, h) in handles.into_iter().enumerate() {
            assert_eq!(h.wait().unwrap(), vec![2.0 * k as f32, 0.0]);
        }
        let s = srv.metrics().snapshot();
        assert_eq!(s.requests, 64);
        assert!(s.mean_batch > 1.0, "no batching happened: {s:?}");
        srv.shutdown();
    }

    #[test]
    fn metrics_text_reflects_traffic() {
        let srv = Server::start(EchoBackend::new(3, 8), ServerConfig::default());
        let h = srv.submit(vec![1.0, 2.0, 3.0]).unwrap();
        h.wait().unwrap();
        let text = srv.metrics_text();
        assert!(text.contains("polymem_requests_total 1"), "{text}");
        assert!(text.contains("polymem_request_latency_us_count 1"), "{text}");
        srv.shutdown();
    }

    #[test]
    fn span_chains_complete_per_request() {
        let srv = Server::start(EchoBackend::new(2, 4), ServerConfig::default());
        let hs: Vec<_> =
            (0..10).map(|k| srv.submit(vec![k as f32, 0.0]).unwrap()).collect();
        for h in hs {
            h.wait().unwrap();
        }
        srv.shutdown();
        assert_eq!(srv.recorder().spans_started(), 10);
        let chains = srv.recorder().chains();
        assert_eq!(chains.len(), 10, "one chain per accepted request");
        for (span, c) in &chains {
            assert!(c.is_complete(), "span {span} incomplete: {c:?}");
        }
        let text = srv.metrics_text();
        assert!(text.contains("polymem_spans_started_total 10"), "{text}");
        // chrome export parses and every E has a preceding B
        let j = crate::util::json::parse(&srv.trace_chrome_json()).unwrap();
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!evs.is_empty());
        let mut depth = 0i64;
        for e in evs {
            match e.get("ph").unwrap().as_str().unwrap() {
                "B" => depth += 1,
                "E" => {
                    depth -= 1;
                    assert!(depth >= 0, "E before matching B");
                }
                _ => {}
            }
        }
        assert_eq!(depth, 0, "unbalanced trace");
    }

    #[test]
    fn bounded_recorder_never_perturbs_responses() {
        // a recorder far smaller than the traffic must overwrite
        // silently — every response still correct, no chain corruption
        // visible to callers
        let cfg = ServerConfig { span_cap: 8, ..Default::default() };
        let srv = Server::start(EchoBackend::new(1, 4), cfg);
        let hs: Vec<_> = (0..50).map(|k| srv.submit(vec![k as f32]).unwrap()).collect();
        for (k, h) in hs.into_iter().enumerate() {
            assert_eq!(h.wait().unwrap(), vec![2.0 * k as f32]);
        }
        srv.shutdown();
        assert!(srv.recorder().len() <= 8);
        assert!(srv.recorder().overwritten() > 0, "tiny ring never wrapped");
        assert_eq!(srv.metrics().snapshot().requests, 50);
    }

    #[test]
    fn wrong_input_len_rejected() {
        let srv = Server::start(EchoBackend::new(3, 8), ServerConfig::default());
        assert!(srv.submit(vec![1.0]).is_err());
        srv.shutdown();
    }

    #[test]
    fn ordering_preserved_within_stream() {
        let srv = Server::start(EchoBackend::new(1, 4), ServerConfig::default());
        let hs: Vec<_> = (0..20).map(|k| srv.submit(vec![k as f32]).unwrap()).collect();
        for (k, h) in hs.into_iter().enumerate() {
            assert_eq!(h.wait().unwrap(), vec![2.0 * k as f32]);
        }
        srv.shutdown();
    }

    #[test]
    fn backpressure_rejects_over_cap() {
        let cfg = ServerConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            queue_cap: 4,
            ..Default::default()
        };
        let mut be = EchoBackend::new(1, 1);
        be.delay = Duration::from_millis(50);
        let srv = Server::start(be, cfg);
        let mut oks = 0;
        let mut rejects = 0;
        let mut handles = vec![];
        for k in 0..32 {
            match srv.submit(vec![k as f32]) {
                Ok(h) => {
                    oks += 1;
                    handles.push(h);
                }
                Err(_) => rejects += 1,
            }
        }
        assert!(rejects > 0, "queue cap never hit");
        assert!(oks >= 4);
        for h in handles {
            let _ = h.wait();
        }
        srv.shutdown();
    }

    #[test]
    fn rejected_submit_releases_backpressure_slot() {
        // regression: the "server stopped" path used to keep the
        // queued slot it had taken, permanently shrinking queue_cap
        let cfg = ServerConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            queue_cap: 2,
            ..Default::default()
        };
        let srv = Server::start(EchoBackend::new(1, 1), cfg);
        srv.shutdown();
        for _ in 0..8 {
            let e = srv.submit(vec![1.0]).unwrap_err().to_string();
            // with the leak, slot 3+ would fail as "queue full" instead
            assert!(e.contains("server stopped"), "leaked slot surfaced as: {e}");
        }
        assert_eq!(srv.queued(), 0, "rejected submits must not hold slots");
    }

    #[test]
    fn shutdown_drains_accepted_requests() {
        // regression: shutdown used to flip a flag the worker only saw
        // from its Empty branch via 5 ms polls; accepted requests could
        // be dropped without a response. Dropping the sender makes the
        // drain deterministic: shutdown() returns only after every
        // accepted request has been answered.
        let cfg = ServerConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(50),
            queue_cap: 1024,
            ..Default::default()
        };
        let mut be = EchoBackend::new(1, 4);
        be.delay = Duration::from_millis(1);
        let srv = Server::start(be, cfg);
        let handles: Vec<_> =
            (0..64).map(|k| srv.submit(vec![k as f32]).unwrap()).collect();
        srv.shutdown();
        for (k, h) in handles.into_iter().enumerate() {
            assert_eq!(
                h.wait().unwrap(),
                vec![2.0 * k as f32],
                "request {k} dropped across shutdown"
            );
        }
        assert_eq!(srv.queued(), 0);
    }

    #[test]
    fn concurrent_shutdown_never_drops_accepted_requests() {
        // accepted ⇒ answered, even when submits race the shutdown
        for _ in 0..10 {
            let mut be = EchoBackend::new(1, 4);
            be.delay = Duration::from_micros(300);
            let cfg = ServerConfig {
                max_batch: 4,
                max_wait: Duration::from_micros(200),
                queue_cap: 256,
                ..Default::default()
            };
            let srv = std::sync::Arc::new(Server::start(be, cfg));
            let submitter = std::thread::spawn({
                let srv = srv.clone();
                move || {
                    let mut handles = vec![];
                    for k in 0..100_000 {
                        match srv.submit(vec![k as f32]) {
                            Ok(h) => handles.push((k, h)),
                            // backpressure rejects are expected mid-run;
                            // only the shutdown rejection ends the race
                            Err(e) if e.to_string().contains("server stopped") => break,
                            Err(_) => std::thread::yield_now(),
                        }
                    }
                    handles
                }
            });
            std::thread::sleep(Duration::from_micros(500));
            srv.shutdown();
            let handles = submitter.join().unwrap();
            for (k, h) in handles {
                assert_eq!(
                    h.wait().unwrap(),
                    vec![2.0 * k as f32],
                    "accepted request {k} lost in shutdown race"
                );
            }
            assert_eq!(srv.queued(), 0);
        }
    }
}
