//! The serving loop: submission queue → batcher → backend worker.
//!
//! One worker thread owns the backend (PJRT executables are not Sync);
//! callers submit from any thread and block on (or poll) a per-request
//! response channel.

use super::backend::Backend;
use crate::util::error::Result;
use super::batcher::{BatchPolicy, Batcher, Flush};
use super::metrics::Metrics;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Server configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Bound on queued requests (backpressure): submits fail fast
    /// beyond it.
    pub queue_cap: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_cap: 1024,
        }
    }
}

struct Request {
    input: Vec<f32>,
    enqueued: Instant,
    respond: Sender<Result<Vec<f32>>>,
}

/// Handle to a response.
pub struct ResponseHandle {
    rx: Receiver<Result<Vec<f32>>>,
}

impl ResponseHandle {
    /// Block until the response arrives.
    pub fn wait(self) -> Result<Vec<f32>> {
        self.rx
            .recv()
            .map_err(|_| crate::format_err!("server dropped the request"))?
    }

    /// Non-blocking poll.
    pub fn try_take(&self) -> Option<Result<Vec<f32>>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                Some(Err(crate::format_err!("server dropped the request")))
            }
        }
    }
}

/// Batching inference server.
pub struct Server {
    tx: Sender<Request>,
    queued: Arc<Mutex<usize>>,
    cfg: ServerConfig,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    worker: Option<std::thread::JoinHandle<()>>,
    input_len: usize,
}

impl Server {
    /// Start the worker thread over a backend built by `factory` *on*
    /// the worker thread (PJRT executables are not `Send`, so they must
    /// be created where they run). The factory returns the backend plus
    /// its per-request input length.
    pub fn start_with<B, F>(factory: F, cfg: ServerConfig) -> Result<Server>
    where
        B: Backend,
        F: FnOnce() -> Result<B> + Send + 'static,
    {
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<Result<usize>>();
        let metrics = Arc::new(Metrics::new());
        let stop = Arc::new(AtomicBool::new(false));
        let queued = Arc::new(Mutex::new(0usize));
        let worker = std::thread::Builder::new()
            .name("polymem-serve".into())
            .spawn({
                let metrics = metrics.clone();
                let stop = stop.clone();
                let queued = queued.clone();
                move || {
                    let backend = match factory() {
                        Ok(b) => {
                            let _ = ready_tx.send(Ok(b.input_len()));
                            b
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                    };
                    worker_loop(backend, cfg, rx, metrics, stop, queued)
                }
            })
            .expect("spawning server worker");
        let input_len = ready_rx
            .recv()
            .map_err(|_| crate::format_err!("server worker died during startup"))??;
        Ok(Server {
            tx,
            queued,
            cfg,
            metrics,
            stop,
            worker: Some(worker),
            input_len,
        })
    }

    /// Start over an already-constructed (Send) backend.
    pub fn start<B: Backend + Send>(backend: B, cfg: ServerConfig) -> Server {
        Server::start_with(move || Ok(backend), cfg).expect("infallible factory")
    }

    /// Submit one request. Fails fast when the queue is saturated
    /// (backpressure) or the input length is wrong.
    pub fn submit(&self, input: Vec<f32>) -> Result<ResponseHandle> {
        crate::ensure!(
            input.len() == self.input_len,
            "input length {} != expected {}",
            input.len(),
            self.input_len
        );
        {
            let mut q = self.queued.lock().unwrap();
            crate::ensure!(*q < self.cfg.queue_cap, "queue full ({} requests)", *q);
            *q += 1;
        }
        let (rtx, rrx) = channel();
        self.tx
            .send(Request { input, enqueued: Instant::now(), respond: rtx })
            .map_err(|_| crate::format_err!("server stopped"))?;
        Ok(ResponseHandle { rx: rrx })
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Prometheus-style plain-text rendering of the current metrics
    /// (what a scrape endpoint would serve).
    pub fn metrics_text(&self) -> String {
        self.metrics.snapshot().render_text()
    }

    /// Stop the worker and wait for it to drain.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop<B: Backend>(
    mut backend: B,
    cfg: ServerConfig,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    queued: Arc<Mutex<usize>>,
) {
    let policy = BatchPolicy::new(cfg.max_batch.min(backend.max_batch()), cfg.max_wait);
    let mut batcher = Batcher::new(policy);
    let mut pending: Vec<Request> = Vec::new();

    loop {
        // pull everything currently queued
        loop {
            match rx.try_recv() {
                Ok(req) => {
                    batcher.push(req.enqueued);
                    pending.push(req);
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    // all senders gone: drain and exit
                    flush_all(&mut backend, &mut pending, &metrics, &queued);
                    return;
                }
            }
        }
        match batcher.poll(Instant::now()) {
            Flush::Now => {
                let n = batcher.take(Instant::now());
                execute_batch(&mut backend, &mut pending, n, &metrics, &queued);
            }
            Flush::Wait(d) => {
                // sleep until deadline or next arrival
                match rx.recv_timeout(d.min(Duration::from_millis(5))) {
                    Ok(req) => {
                        batcher.push(req.enqueued);
                        pending.push(req);
                    }
                    Err(_) => {}
                }
            }
            Flush::Empty => {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                match rx.recv_timeout(Duration::from_millis(5)) {
                    Ok(req) => {
                        batcher.push(req.enqueued);
                        pending.push(req);
                    }
                    Err(_) => {}
                }
            }
        }
    }
}

fn flush_all<B: Backend>(
    backend: &mut B,
    pending: &mut Vec<Request>,
    metrics: &Metrics,
    queued: &Mutex<usize>,
) {
    while !pending.is_empty() {
        let n = pending.len().min(backend.max_batch());
        execute_batch(backend, pending, n, metrics, queued);
    }
}

fn execute_batch<B: Backend>(
    backend: &mut B,
    pending: &mut Vec<Request>,
    n: usize,
    metrics: &Metrics,
    queued: &Mutex<usize>,
) {
    if n == 0 {
        return;
    }
    let batch: Vec<Request> = pending.drain(..n).collect();
    {
        let mut q = queued.lock().unwrap();
        *q = q.saturating_sub(n);
    }
    let in_len = backend.input_len();
    let out_len = backend.output_len();
    let mut packed = Vec::with_capacity(n * in_len);
    for r in &batch {
        packed.extend_from_slice(&r.input);
    }
    match backend.infer(&packed, n) {
        Ok(out) => {
            let now = Instant::now();
            let latencies: Vec<Duration> =
                batch.iter().map(|r| now.duration_since(r.enqueued)).collect();
            metrics.record_batch(n, &latencies);
            for (k, r) in batch.into_iter().enumerate() {
                let slice = out[k * out_len..(k + 1) * out_len].to_vec();
                let _ = r.respond.send(Ok(slice));
            }
        }
        Err(e) => {
            metrics.record_error(n);
            for r in batch {
                let _ = r.respond.send(Err(crate::format_err!("inference failed: {e}")));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::EchoBackend;

    #[test]
    fn roundtrip_single() {
        let srv = Server::start(EchoBackend::new(3, 8), ServerConfig::default());
        let h = srv.submit(vec![1.0, 2.0, 3.0]).unwrap();
        assert_eq!(h.wait().unwrap(), vec![2.0, 4.0, 6.0]);
        let s = srv.metrics().snapshot();
        assert_eq!(s.requests, 1);
        srv.shutdown();
    }

    #[test]
    fn batches_form_under_load() {
        let cfg = ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(20),
            queue_cap: 1024,
        };
        let mut be = EchoBackend::new(2, 8);
        be.delay = Duration::from_millis(2); // slow enough to queue up
        let srv = Server::start(be, cfg);
        let handles: Vec<_> = (0..64)
            .map(|k| srv.submit(vec![k as f32, 0.0]).unwrap())
            .collect();
        for (k, h) in handles.into_iter().enumerate() {
            assert_eq!(h.wait().unwrap(), vec![2.0 * k as f32, 0.0]);
        }
        let s = srv.metrics().snapshot();
        assert_eq!(s.requests, 64);
        assert!(s.mean_batch > 1.0, "no batching happened: {s:?}");
        srv.shutdown();
    }

    #[test]
    fn metrics_text_reflects_traffic() {
        let srv = Server::start(EchoBackend::new(3, 8), ServerConfig::default());
        let h = srv.submit(vec![1.0, 2.0, 3.0]).unwrap();
        h.wait().unwrap();
        let text = srv.metrics_text();
        assert!(text.contains("polymem_requests_total 1"), "{text}");
        assert!(text.contains("polymem_request_latency_us_count 1"), "{text}");
        srv.shutdown();
    }

    #[test]
    fn wrong_input_len_rejected() {
        let srv = Server::start(EchoBackend::new(3, 8), ServerConfig::default());
        assert!(srv.submit(vec![1.0]).is_err());
        srv.shutdown();
    }

    #[test]
    fn ordering_preserved_within_stream() {
        let srv = Server::start(EchoBackend::new(1, 4), ServerConfig::default());
        let hs: Vec<_> = (0..20).map(|k| srv.submit(vec![k as f32]).unwrap()).collect();
        for (k, h) in hs.into_iter().enumerate() {
            assert_eq!(h.wait().unwrap(), vec![2.0 * k as f32]);
        }
        srv.shutdown();
    }

    #[test]
    fn backpressure_rejects_over_cap() {
        let cfg = ServerConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            queue_cap: 4,
        };
        let mut be = EchoBackend::new(1, 1);
        be.delay = Duration::from_millis(50);
        let srv = Server::start(be, cfg);
        let mut oks = 0;
        let mut rejects = 0;
        let mut handles = vec![];
        for k in 0..32 {
            match srv.submit(vec![k as f32]) {
                Ok(h) => {
                    oks += 1;
                    handles.push(h);
                }
                Err(_) => rejects += 1,
            }
        }
        assert!(rejects > 0, "queue cap never hit");
        assert!(oks >= 4);
        for h in handles {
            let _ = h.wait();
        }
        srv.shutdown();
    }
}
