//! Dynamic batching policy — pure logic, unit-testable without threads.
//!
//! The policy is the standard serving trade-off: flush when the batch
//! is full, or when the oldest queued request has waited `max_wait`,
//! or (in eager mode) as soon as the queue drains.

use std::time::{Duration, Instant};

/// Batching policy parameters.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Flush at this many requests.
    pub max_batch: usize,
    /// Flush when the oldest request has waited this long.
    pub max_wait: Duration,
}

impl BatchPolicy {
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        assert!(max_batch >= 1);
        BatchPolicy { max_batch, max_wait }
    }
}

/// Decision produced by [`Batcher::poll`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Flush {
    /// Keep accumulating; re-poll within the given duration.
    Wait(Duration),
    /// Execute the current batch now.
    Now,
    /// Nothing queued.
    Empty,
}

/// Accumulates request timestamps and decides when to flush.
#[derive(Debug)]
pub struct Batcher {
    policy: BatchPolicy,
    pending: usize,
    oldest: Option<Instant>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { policy, pending: 0, oldest: None }
    }

    pub fn pending(&self) -> usize {
        self.pending
    }

    /// Record an enqueued request.
    pub fn push(&mut self, now: Instant) {
        if self.pending == 0 {
            self.oldest = Some(now);
        }
        self.pending += 1;
    }

    /// Should the worker flush?
    pub fn poll(&self, now: Instant) -> Flush {
        if self.pending == 0 {
            return Flush::Empty;
        }
        if self.pending >= self.policy.max_batch {
            return Flush::Now;
        }
        let waited = now.duration_since(self.oldest.unwrap());
        if waited >= self.policy.max_wait {
            Flush::Now
        } else {
            Flush::Wait(self.policy.max_wait - waited)
        }
    }

    /// Remove up to `max_batch` requests from the accounting; returns
    /// the batch size taken. Caller drains the actual queue.
    pub fn take(&mut self, now: Instant) -> usize {
        let n = self.pending.min(self.policy.max_batch);
        self.pending -= n;
        self.oldest = if self.pending > 0 { Some(now) } else { None };
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pol(max_batch: usize, wait_ms: u64) -> BatchPolicy {
        BatchPolicy::new(max_batch, Duration::from_millis(wait_ms))
    }

    #[test]
    fn empty_queue() {
        let b = Batcher::new(pol(4, 10));
        assert_eq!(b.poll(Instant::now()), Flush::Empty);
    }

    #[test]
    fn flushes_on_full_batch() {
        let mut b = Batcher::new(pol(3, 1000));
        let t = Instant::now();
        b.push(t);
        b.push(t);
        assert!(matches!(b.poll(t), Flush::Wait(_)));
        b.push(t);
        assert_eq!(b.poll(t), Flush::Now);
        assert_eq!(b.take(t), 3);
        assert_eq!(b.poll(t), Flush::Empty);
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b = Batcher::new(pol(100, 10));
        let t0 = Instant::now();
        b.push(t0);
        match b.poll(t0) {
            Flush::Wait(d) => assert!(d <= Duration::from_millis(10)),
            other => panic!("expected Wait, got {other:?}"),
        }
        let later = t0 + Duration::from_millis(11);
        assert_eq!(b.poll(later), Flush::Now);
        assert_eq!(b.take(later), 1);
    }

    #[test]
    fn take_caps_at_max_batch() {
        let mut b = Batcher::new(pol(4, 1));
        let t = Instant::now();
        for _ in 0..10 {
            b.push(t);
        }
        assert_eq!(b.take(t), 4);
        assert_eq!(b.pending(), 6);
        // remaining requests restart the wait clock
        assert!(matches!(b.poll(t), Flush::Now | Flush::Wait(_)));
    }

    #[test]
    fn wait_decreases_over_time() {
        let mut b = Batcher::new(pol(10, 100));
        let t0 = Instant::now();
        b.push(t0);
        let Flush::Wait(d1) = b.poll(t0 + Duration::from_millis(10)) else {
            panic!()
        };
        let Flush::Wait(d2) = b.poll(t0 + Duration::from_millis(50)) else {
            panic!()
        };
        assert!(d2 < d1);
    }
}
