//! Dynamic batching policy — pure logic, unit-testable without threads.
//!
//! The policy is the standard serving trade-off: flush when the batch
//! is full, or when the oldest queued request has waited `max_wait`,
//! or (in eager mode) as soon as the queue drains.
//!
//! Two flush-sizing modes sit on top of the same accounting:
//!
//! * **fixed** — take `max_batch` requests (the classic policy);
//! * **cost-aware bucketized** — [`choose_bucket`] consults a table of
//!   per-bucket predicted costs (off-chip bytes and pipelined service
//!   seconds from `cost::evaluate` over the plan cache's compiled
//!   artifacts) and picks the bucket minimizing amortized off-chip
//!   bytes per served request.
//!
//! The batcher tracks every request's enqueue timestamp **and span
//! id** in a `VecDeque`, so a partial flush leaves survivors with
//! their true arrival times (the deadline for the next flush is still
//! measured from when they actually arrived, never restarted) and
//! every flush reports exactly which requests it served — the span ids
//! [`Batcher::take`] returns are what the server's flight recorder
//! stitches into per-request chains, and the identity "ids taken ==
//! requests executed" is asserted on every batch.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Batching policy parameters.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Flush at this many requests.
    pub max_batch: usize,
    /// Flush when the oldest request has waited this long.
    pub max_wait: Duration,
}

impl BatchPolicy {
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        assert!(max_batch >= 1);
        BatchPolicy { max_batch, max_wait }
    }
}

/// Decision produced by [`Batcher::poll`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Flush {
    /// Keep accumulating; re-poll within the given duration.
    Wait(Duration),
    /// Execute the current batch now.
    Now,
    /// Nothing queued.
    Empty,
}

/// Predicted cost of executing one batch at a precompiled bucket size
/// (from `cost::evaluate` over the bucket's `(Program, MemoryPlan)`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BucketCost {
    /// The compiled batch size.
    pub batch: usize,
    /// Predicted off-chip DRAM bytes of one execution at this bucket.
    pub offchip_bytes: i64,
    /// Predicted pipelined service seconds of one execution.
    pub service_seconds: f64,
}

/// Pick the flush size for `pending` queued requests from a table of
/// per-bucket predicted costs: minimize amortized off-chip bytes per
/// *served* request, `offchip(bucket) / min(pending, bucket)` — a
/// bucket larger than `pending` still pays its full-batch traffic
/// (padding), a bucket smaller leaves survivors queued. Ties prefer
/// serving more requests, then the smaller bucket.
///
/// Returns `(take, bucket)` — how many requests to serve now and the
/// bucket charged — or `None` when nothing is pending or the table is
/// empty.
pub fn choose_bucket(pending: usize, costs: &[BucketCost]) -> Option<(usize, BucketCost)> {
    if pending == 0 {
        return None;
    }
    let mut best: Option<(usize, BucketCost, f64)> = None;
    for &c in costs {
        if c.batch == 0 {
            continue;
        }
        let take = pending.min(c.batch);
        let amortized = c.offchip_bytes as f64 / take as f64;
        let better = match &best {
            None => true,
            Some((bt, bc, ba)) => {
                amortized < *ba
                    || (amortized == *ba && take > *bt)
                    || (amortized == *ba && take == *bt && c.batch < bc.batch)
            }
        };
        if better {
            best = Some((take, c, amortized));
        }
    }
    best.map(|(take, c, _)| (take, c))
}

/// Accumulates request timestamps + span ids and decides when to
/// flush.
#[derive(Debug)]
pub struct Batcher {
    policy: BatchPolicy,
    /// `(enqueue time, span id)` of every queued request, in arrival
    /// order.
    queue: VecDeque<(Instant, u64)>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { policy, queue: VecDeque::new() }
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// The policy's fixed flush size.
    pub fn max_batch(&self) -> usize {
        self.policy.max_batch
    }

    /// Enqueue time of the oldest pending request.
    pub fn oldest(&self) -> Option<Instant> {
        self.queue.front().map(|&(t, _)| t)
    }

    /// Record an enqueued request under its tracing span id.
    pub fn push(&mut self, now: Instant, span: u64) {
        self.queue.push_back((now, span));
    }

    /// Should the worker flush?
    pub fn poll(&self, now: Instant) -> Flush {
        let Some(&(front, _)) = self.queue.front() else {
            return Flush::Empty;
        };
        if self.queue.len() >= self.policy.max_batch {
            return Flush::Now;
        }
        // saturates to zero when `front` is in the future
        let waited = now.duration_since(front);
        if waited >= self.policy.max_wait {
            Flush::Now
        } else {
            Flush::Wait(self.policy.max_wait - waited)
        }
    }

    /// Remove the `n` oldest requests from the accounting (capped at
    /// what is pending); returns their span ids in arrival order.
    /// Survivors keep their original enqueue times, so their deadline
    /// still dates from when they actually arrived. Caller drains the
    /// actual queue and must serve exactly these requests.
    pub fn take(&mut self, n: usize) -> Vec<u64> {
        let k = n.min(self.queue.len());
        self.queue.drain(..k).map(|(_, span)| span).collect()
    }

    /// Fixed-policy flush: take up to `max_batch`.
    pub fn take_max(&mut self) -> Vec<u64> {
        self.take(self.policy.max_batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pol(max_batch: usize, wait_ms: u64) -> BatchPolicy {
        BatchPolicy::new(max_batch, Duration::from_millis(wait_ms))
    }

    #[test]
    fn empty_queue() {
        let b = Batcher::new(pol(4, 10));
        assert_eq!(b.poll(Instant::now()), Flush::Empty);
    }

    #[test]
    fn flushes_on_full_batch() {
        let mut b = Batcher::new(pol(3, 1000));
        let t = Instant::now();
        b.push(t, 1);
        b.push(t, 2);
        assert!(matches!(b.poll(t), Flush::Wait(_)));
        b.push(t, 3);
        assert_eq!(b.poll(t), Flush::Now);
        // the flush reports exactly the span ids it served, in order
        assert_eq!(b.take_max(), vec![1, 2, 3]);
        assert_eq!(b.poll(t), Flush::Empty);
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b = Batcher::new(pol(100, 10));
        let t0 = Instant::now();
        b.push(t0, 7);
        match b.poll(t0) {
            Flush::Wait(d) => assert!(d <= Duration::from_millis(10)),
            other => panic!("expected Wait, got {other:?}"),
        }
        let later = t0 + Duration::from_millis(11);
        assert_eq!(b.poll(later), Flush::Now);
        assert_eq!(b.take_max(), vec![7]);
    }

    #[test]
    fn take_caps_at_max_batch() {
        let mut b = Batcher::new(pol(4, 1));
        let t = Instant::now();
        for k in 0..10 {
            b.push(t, k);
        }
        assert_eq!(b.take_max(), vec![0, 1, 2, 3]);
        assert_eq!(b.pending(), 6);
        // leftovers keep their true enqueue time: still overdue (or
        // immediately full again) — the wait clock does NOT restart
        assert_eq!(b.oldest(), Some(t));
        assert_eq!(b.poll(t + Duration::from_millis(1)), Flush::Now);
    }

    #[test]
    fn leftovers_keep_enqueue_times() {
        // regression: take() used to reset `oldest = now` for the
        // surviving requests, letting them wait up to 2× max_wait
        let mut b = Batcher::new(pol(4, 10));
        let t0 = Instant::now();
        for k in 0..6 {
            b.push(t0, k);
        }
        assert_eq!(b.take(4).len(), 4);
        assert_eq!(b.pending(), 2);
        // at t0+4ms the survivors have 6ms left, not a fresh 10ms
        match b.poll(t0 + Duration::from_millis(4)) {
            Flush::Wait(d) => assert!(
                d <= Duration::from_millis(6),
                "wait clock restarted: {d:?} left"
            ),
            other => panic!("expected Wait, got {other:?}"),
        }
        // and at t0+10ms they are due exactly on their own deadline
        assert_eq!(b.poll(t0 + Duration::from_millis(10)), Flush::Now);
    }

    #[test]
    fn partial_take_tracks_per_request_ages() {
        let mut b = Batcher::new(pol(8, 10));
        let t0 = Instant::now();
        let t1 = t0 + Duration::from_millis(5);
        b.push(t0, 10);
        b.push(t1, 11);
        assert_eq!(b.take(1), vec![10]); // serves the t0 request
        assert_eq!(b.oldest(), Some(t1));
        // the t1 request's deadline is t1+10ms, not t0+10ms
        assert!(matches!(b.poll(t0 + Duration::from_millis(11)), Flush::Wait(_)));
        assert_eq!(b.poll(t1 + Duration::from_millis(10)), Flush::Now);
    }

    #[test]
    fn wait_decreases_over_time() {
        let mut b = Batcher::new(pol(10, 100));
        let t0 = Instant::now();
        b.push(t0, 0);
        let Flush::Wait(d1) = b.poll(t0 + Duration::from_millis(10)) else {
            panic!()
        };
        let Flush::Wait(d2) = b.poll(t0 + Duration::from_millis(50)) else {
            panic!()
        };
        assert!(d2 < d1);
    }

    // synthetic bucket table: off-chip bytes = weights + batch ×
    // activations, the shape the plan cache produces for real models
    fn table(weights: i64, act: i64, buckets: &[usize]) -> Vec<BucketCost> {
        buckets
            .iter()
            .map(|&b| BucketCost {
                batch: b,
                offchip_bytes: weights + act * b as i64,
                service_seconds: 1e-3 * b as f64,
            })
            .collect()
    }

    #[test]
    fn choose_bucket_amortizes_weights() {
        let t = table(1000, 10, &[1, 2, 4, 8]);
        // a full queue always amortizes best on the largest bucket
        let (take, c) = choose_bucket(12, &t).unwrap();
        assert_eq!((take, c.batch), (8, 8));
        // pending=3: bucket 4 pads one slot but amortizes the weights
        // over 3 requests at lower total bytes than bucket 8 would
        let (take, c) = choose_bucket(3, &t).unwrap();
        assert_eq!(take, 3);
        assert_eq!(c.batch, 4);
        // a single request is cheapest on the batch-1 plan only when
        // activations dominate; with heavy weights it still prefers
        // the smallest bucket (same amortization, fewer total bytes)
        let (take, c) = choose_bucket(1, &t).unwrap();
        assert_eq!((take, c.batch), (1, 1));
    }

    #[test]
    fn choose_bucket_prefers_serving_more_on_ties() {
        // zero activation cost: every bucket has identical total bytes,
        // so amortization strictly favors serving more requests
        let t = table(1000, 0, &[1, 2, 4]);
        let (take, c) = choose_bucket(3, &t).unwrap();
        assert_eq!(take, 3);
        assert_eq!(c.batch, 4);
    }

    #[test]
    fn choose_bucket_empty_inputs() {
        assert!(choose_bucket(0, &table(1, 1, &[1])).is_none());
        assert!(choose_bucket(5, &[]).is_none());
    }
}
