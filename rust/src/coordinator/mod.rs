//! L3 serving coordinator.
//!
//! A batching inference server in the vLLM-router mold, scaled to this
//! repo's inference-compiler scope: requests enter a bounded queue, a
//! batcher thread groups them under a size/deadline policy, a worker
//! executes each batch on a [`Backend`] (the PJRT runtime or the
//! plan-cache-backed `serve::PlannedBackend` in production, mocks in
//! tests), and metrics record the latency distribution. Built on std
//! threads + channels (tokio is not in the offline crate cache; the
//! request path is compute-bound, not I/O-bound, so threads are a
//! faithful substitute).
//!
//! Flush sizing is cost-aware when the backend publishes a
//! [`BucketCost`] table: each flush picks the precompiled batch-size
//! bucket minimizing predicted off-chip bytes per served request (see
//! [`batcher::choose_bucket`]); otherwise the classic fixed
//! `max_batch` policy applies.

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod server;

pub use backend::{Backend, BatchActuals, EchoBackend, PjrtBackend};
pub use batcher::{choose_bucket, BatchPolicy, Batcher, BucketCost};
pub use metrics::{BucketDrift, Metrics};
pub use server::{Server, ServerConfig};
