//! `polymem` — CLI for the compiler, simulator and serving layer.
//!
//! Commands:
//! * `compile`  — run the pass pipeline on a model, print pass stats;
//! * `simulate` — compile + replay on the accelerator model, print the
//!   traffic report (optionally JSON);
//! * `e1` / `e2` — regenerate the paper's two experiments as tables;
//! * `serve`    — load an AOT artifact and run the batching server over
//!   a synthetic request stream, printing latency/throughput;
//! * `bench-regress` — gate a fresh benchmark JSON record against a
//!   committed baseline with per-metric tolerances.

use polymem::accel::{simulate, AccelConfig};
use polymem::coordinator::{PjrtBackend, Server, ServerConfig};
use polymem::ir::Graph;
use polymem::passes::manager::{BankMode, PassManager};
use polymem::report;
use polymem::runtime::RuntimeClient;
use polymem::util::cli::{App, Command, Parsed};
use std::time::{Duration, Instant};

fn model_by_name(name: &str, batch: i64) -> Result<Graph, String> {
    polymem::models::by_name(name, batch).ok_or_else(|| {
        format!(
            "unknown model '{name}' (try resnet50|resnet18|wavenet|mlp|transformer|mobilenet|inception)"
        )
    })
}

/// Resolve the workload: `--graph file.json` wins over `--model name`.
fn graph_from_args(p: &Parsed) -> Result<Graph, String> {
    let path = p.get("graph");
    if !path.is_empty() {
        let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
        let j = polymem::util::json::parse(&text).map_err(|e| e.to_string())?;
        let g = polymem::ir::serde::graph_from_json(&j).map_err(|e| e.to_string())?;
        polymem::ir::verify::verify_graph(&g).map_err(|e| e.to_string())?;
        return Ok(g);
    }
    model_by_name(p.get("model"), p.get_usize("batch")? as i64)
}

fn accel_from_args(p: &Parsed) -> Result<AccelConfig, String> {
    let mut cfg = AccelConfig::inferentia_like();
    let path = p.get("accel-config");
    if !path.is_empty() {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {path}: {e}"))?;
        let j = polymem::util::json::parse(&text).map_err(|e| e.to_string())?;
        cfg = AccelConfig::from_json(&j)?;
    }
    if let Ok(b) = p.get_usize("banks") {
        if b > 0 {
            cfg.banks = b;
        }
    }
    if let Ok(kib) = p.get_usize("scratchpad-kib") {
        if kib > 0 {
            // total capacity spans both bank groups
            cfg.bank_bytes = (kib as i64 * 1024) / (2 * cfg.banks as i64);
        }
    }
    if let Ok(c) = p.get_usize("cores") {
        if c > 0 {
            cfg = cfg.with_cores(c);
        }
    }
    Ok(cfg)
}

/// Write the replay's engine timeline as Chrome trace-event JSON when
/// `--trace-out` was given.
fn write_trace_out(p: &Parsed, trace: &polymem::accel::Trace) -> Result<(), String> {
    let path = p.get("trace-out");
    if path.is_empty() {
        return Ok(());
    }
    let j = trace.to_chrome_json();
    let n = j
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .map(|a| a.len())
        .unwrap_or(0);
    std::fs::write(path, j.to_string_compact())
        .map_err(|e| format!("writing {path}: {e}"))?;
    println!("wrote {path} ({n} trace events; open in chrome://tracing or Perfetto)");
    Ok(())
}

fn pm_from_args(p: &Parsed) -> Result<PassManager, String> {
    let mode = BankMode::parse(p.get("bank-mode"))
        .ok_or_else(|| format!("bad --bank-mode '{}'", p.get("bank-mode")))?;
    Ok(PassManager {
        enable_dme: !p.has_flag("no-dme"),
        bank_mode: mode,
        verify: !p.has_flag("no-verify"),
        ..Default::default()
    })
}

fn cmd_compile(p: &Parsed) -> Result<(), String> {
    let g = graph_from_args(p)?;
    let pm = pm_from_args(p)?;
    let t0 = Instant::now();
    let rep = pm.run(g).map_err(|e| e.to_string())?;
    println!("compiled '{}' in {:?}", p.get("model"), t0.elapsed());
    if let Some(dme) = &rep.dme {
        println!(
            "  DME: {}/{} load-store pairs eliminated, {} freed, {} iterations ({:?})",
            dme.pairs_eliminated,
            dme.pairs_before,
            report::mb(dme.bytes_eliminated),
            dme.iterations,
            rep.dme_time
        );
    }
    if let Some(bank) = &rep.bank {
        println!(
            "  bank mapping ({:?}): {} remap copies, {} moved, {} edges clean ({:?})",
            pm.bank_mode,
            bank.stats.copies_inserted,
            report::mb(bank.stats.copy_bytes),
            bank.stats.edges_matched,
            rep.bank_time
        );
    }
    println!(
        "  program: {} nests, {} copy nests, {} nodes",
        rep.program.nests.len(),
        rep.program.load_store_pairs(),
        rep.program.graph.nodes().len()
    );
    Ok(())
}

/// `simulate --serve-trace-out`: compile the model's serving buckets,
/// run a traced virtual-time load simulation over them, and write the
/// request span chains as Chrome trace-event JSON.
fn cmd_serve_trace(p: &Parsed, cfg: &AccelConfig) -> Result<(), String> {
    use polymem::coordinator::BucketCost;
    use polymem::obs::FlightRecorder;
    use polymem::serve::{
        run_load_traced, Arrivals, LoadSimConfig, PlanCache, PlanCacheConfig, SloSpec,
    };

    let model = p.get("model");
    let buckets: Vec<i64> = p
        .get("serve-buckets")
        .split(',')
        .map(|s| s.trim().parse::<i64>().map_err(|_| format!("bad --serve-buckets entry '{s}'")))
        .collect::<Result<_, _>>()?;
    let requests = p.get_usize("serve-requests")?;
    // staged-greedy compilation keeps the smoke path fast; the joint
    // search's artifacts trace identically (bench_serving covers them)
    let mut cache = PlanCache::new(
        model,
        PlanCacheConfig { accel: cfg.clone(), joint: false, verify: false, max_entries: 0 },
    );
    let arts = cache.compile_buckets(&buckets).map_err(|e| e.to_string())?;
    let costs: Vec<BucketCost> = arts
        .iter()
        .map(|a| BucketCost {
            batch: a.batch as usize,
            offchip_bytes: a.cost.offchip_total(),
            service_seconds: a.service_seconds,
        })
        .collect();
    let svc_max = costs.iter().map(|c| c.service_seconds).fold(0.0f64, f64::max);

    let recorder = FlightRecorder::new((requests * 8).max(1024));
    let sim_cfg = LoadSimConfig {
        arrivals: Arrivals::Closed { clients: 8, requests },
        max_wait: Duration::from_secs_f64(svc_max * 2.0),
        queue_cap: 256,
        slo: Some(SloSpec {
            latency: Duration::from_secs_f64(svc_max * 8.0),
            target: 0.99,
        }),
    };
    let rep = run_load_traced(&costs, &sim_cfg, &format!("{model}/serve-trace"), Some(&recorder));
    println!(
        "serve trace: {model} on {} — {} requests, {:.0} qps, p50 {:?} p99 {:?}, \
         {:.2} KiB/req, mean batch {:.2}",
        cfg.name,
        rep.completed,
        rep.qps,
        rep.p50(),
        rep.p99(),
        rep.bytes_per_request / 1024.0,
        rep.mean_batch
    );
    if let Some(slo) = &rep.slo {
        println!(
            "  SLO {}us@{:.0}%: attainment {:.4}, error-budget burn {:.2}x",
            slo.objective_us,
            slo.target * 100.0,
            slo.attainment,
            slo.error_budget_burn
        );
    }
    let path = p.get("serve-trace-out");
    let trace = recorder.to_chrome();
    let n = trace.len();
    std::fs::write(path, trace.to_json().to_string_compact())
        .map_err(|e| format!("writing {path}: {e}"))?;
    println!(
        "wrote {path} ({n} trace events from {} spans; open in chrome://tracing or Perfetto)",
        recorder.spans_started()
    );
    Ok(())
}

fn cmd_simulate(p: &Parsed) -> Result<(), String> {
    use polymem::util::json::Json;
    let g = graph_from_args(p)?;
    let pm = pm_from_args(p)?;
    let cfg = accel_from_args(p)?;
    if !p.get("serve-trace-out").is_empty() {
        return cmd_serve_trace(p, &cfg);
    }
    if p.has_flag("profile") {
        polymem::obs::set_enabled(true);
    }
    if cfg.num_cores > 1 {
        return cmd_simulate_sharded(g, &cfg, p);
    }
    let want_plan = p.has_flag("plan");
    let want_tile = p.has_flag("tile");
    let want_opt = p.has_flag("opt");
    if want_plan || want_tile || want_opt {
        return cmd_simulate_compare(g, pm, &cfg, p);
    }
    let top = p.get_usize("top-layers")?;
    let rep = pm.run(g).map_err(|e| e.to_string())?;
    // attribution/timeline side-channels are schedule-proportional, so
    // an event cap of 0 still yields the full telemetry
    let mut trace = polymem::accel::Trace::new(0);
    let sim = simulate(&rep.program, &cfg, Some(&mut trace));
    write_trace_out(p, &trace)?;
    if p.has_flag("json") {
        let mut j = report::sim_to_json(&sim);
        if let Json::Obj(m) = &mut j {
            m.insert(
                "attribution".to_string(),
                report::attribution_json(&rep.program.graph, trace.attr(), top),
            );
            if p.has_flag("profile") {
                m.insert("obs".to_string(), polymem::obs::global().snapshot().to_json());
            }
        }
        println!("{}", j.to_string_pretty());
    } else {
        println!(
            "model={} bank_mode={} accel={}",
            p.get("model"),
            p.get("bank-mode"),
            cfg.name
        );
        println!("{}", sim.traffic.to_json().to_string_pretty());
        println!("on-chip movement total: {}", report::mb(sim.onchip_movement_total()));
        println!("off-chip total:         {}", report::mb(sim.offchip_total()));
        println!("peak scratchpad:        {}", report::mb(sim.peak_scratchpad));
        println!("estimated latency:      {:.3} ms", sim.seconds * 1e3);
        println!("\nper-layer off-chip attribution (top {top}):");
        println!(
            "{}",
            report::attribution_table(&rep.program.graph, trace.attr(), top)
        );
        if p.has_flag("profile") {
            println!("compiler telemetry:");
            print!("{}", polymem::obs::global().snapshot().render_text());
        }
    }
    Ok(())
}

/// `simulate --cores N` (N > 1): pipeline-parallel sharding. Searches
/// the cut-point axis jointly with each stage's memory plan, verifies
/// the combined prediction against a bit-exact multi-engine replay,
/// and prints the per-stage table (or JSON); `--trace-out` writes the
/// steady-state pipeline as Chrome trace-event JSON, one lane per core.
fn cmd_simulate_sharded(
    g: polymem::ir::Graph,
    cfg: &AccelConfig,
    p: &Parsed,
) -> Result<(), String> {
    use polymem::shard::{replay_sharded, search_sharded, ShardOpts};
    use polymem::util::json::Json;

    let opts = ShardOpts {
        // --opt keeps its meaning from the single-core comparison;
        // plain `simulate --cores N` uses the staged-greedy stages
        joint: p.has_flag("opt"),
        verify: !p.has_flag("no-verify"),
        threads: p.get_usize("search-threads").unwrap_or(0),
        ..ShardOpts::default()
    };
    let outcome = search_sharded(&g, cfg, &opts).map_err(|e| e.to_string())?;
    let replay = replay_sharded(&outcome.stages, &outcome.transfer_bytes, cfg)
        .map_err(|e| e.to_string())?;
    if !outcome.cost.bits_eq(&replay) {
        return Err("sharded calibration broke: prediction != multi-engine replay".into());
    }

    if !p.get("trace-out").is_empty() {
        let path = p.get("trace-out");
        let batches = p.get_usize("trace-batches")?;
        let j = outcome.to_chrome_json(batches.max(1));
        let n = j
            .get("traceEvents")
            .and_then(|v| v.as_arr())
            .map(|a| a.len())
            .unwrap_or(0);
        std::fs::write(path, j.to_string_compact())
            .map_err(|e| format!("writing {path}: {e}"))?;
        println!("wrote {path} ({n} trace events; open in chrome://tracing or Perfetto)");
    }

    if p.has_flag("json") {
        let j = Json::obj(vec![
            ("model", Json::Str(p.get("model").to_string())),
            ("accel", cfg.to_json()),
            ("sharded", outcome.to_json()),
        ]);
        println!("{}", j.to_string_pretty());
        return Ok(());
    }

    println!(
        "pipeline-parallel sharding on '{}' ({}, {} cores):\n",
        p.get("model"),
        cfg.name,
        cfg.num_cores
    );
    for (s, stage) in outcome.stages.iter().enumerate() {
        println!(
            "  stage {s}: nodes [{:>3}..{:>3})  compute {:>9.3} ms  off-chip {:>10}  \
             send {:>10}  [{}]",
            stage.start,
            stage.end,
            outcome.cost.stage_seconds[s] * 1e3,
            report::mb(stage.cost.offchip_total()),
            report::mb(outcome.transfer_bytes[s]),
            stage.decision
        );
    }
    println!(
        "\n  steady-state interval: {:>9.3} ms ({:.0} batches/s at saturation)",
        outcome.cost.interval_seconds * 1e3,
        1.0 / outcome.cost.interval_seconds
    );
    println!("  fill latency:          {:>9.3} ms", outcome.cost.latency_seconds * 1e3);
    println!(
        "  off-chip total:        {:>10}",
        report::mb(outcome.cost.offchip_total())
    );
    println!(
        "  inter-core fabric:     {:>10}",
        report::mb(outcome.cost.traffic.intercore_total())
    );
    println!("  calibration:           bit-exact vs multi-engine replay");
    let st = &outcome.stats;
    println!(
        "  search: {} candidates ({} evaluated, {} pruned, {} infeasible), \
         {} stage compiles + {} memo hits in {:.2} s",
        st.candidates,
        st.evaluated,
        st.pruned,
        st.infeasible,
        st.stage_compiles,
        st.memo_hits,
        st.search_seconds
    );
    Ok(())
}

/// The unified `simulate` comparison: one table (and one shared JSON
/// schema) over the requested compiled modes — the dynamic baseline is
/// always included, `--plan` adds the static-plan replay, `--tile` the
/// tiled double-buffer pipeline, `--opt` the joint-optimizer pipeline.
fn cmd_simulate_compare(
    g: polymem::ir::Graph,
    pm_base: PassManager,
    cfg: &AccelConfig,
    p: &Parsed,
) -> Result<(), String> {
    use polymem::accel::{simulate_pipelined, simulate_planned, SimReport};
    use polymem::passes::{AllocStage, OptStage, TileStage};
    use polymem::util::json::Json;

    struct Mode {
        name: &'static str,
        sim: SimReport,
        extras: Vec<(&'static str, Json)>,
        note: String,
    }
    let mut modes: Vec<Mode> = Vec::new();

    // telemetry rides on the most advanced requested mode: its replay
    // gets the Trace, its JSON entry the attribution, and `--trace-out`
    // its engine timeline
    let traced_mode = if p.has_flag("opt") {
        "opt"
    } else if p.has_flag("tile") {
        "tiled"
    } else {
        "planned"
    };
    let top = p.get_usize("top-layers")?;
    let mut attr_table: Option<String> = None;

    // dynamic baseline: the untransformed pipeline output, residency
    // improvised at replay time (the same comparison the benches make)
    let base = pm_base.run(g.clone()).map_err(|e| e.to_string())?;
    modes.push(Mode {
        name: "dynamic",
        sim: simulate(&base.program, cfg, None),
        extras: vec![],
        note: format!("{} nests", base.program.nests.len()),
    });

    if p.has_flag("plan") {
        let mut pm = pm_base.clone();
        pm.alloc = Some(AllocStage::for_accel(cfg.clone()));
        let rep = pm.run(g.clone()).map_err(|e| e.to_string())?;
        let plan = rep.plan.as_ref().expect("alloc stage ran");
        let mut tr = polymem::accel::Trace::new(0);
        let traced = traced_mode == "planned";
        let sim = simulate_planned(&rep.program, plan, cfg, traced.then_some(&mut tr))
            .map_err(|e| e.to_string())?;
        let mut extras = vec![("plan", plan.to_json())];
        if traced {
            extras.push((
                "attribution",
                report::attribution_json(&rep.program.graph, tr.attr(), top),
            ));
            attr_table = Some(report::attribution_table(&rep.program.graph, tr.attr(), top));
            write_trace_out(p, &tr)?;
        }
        let s = &plan.stats;
        modes.push(Mode {
            name: "planned",
            sim,
            extras,
            note: format!(
                "{} spill pairs, {} splits, {} streamed",
                s.spill_pairs, s.window_splits, s.streamed
            ),
        });
    }
    if p.has_flag("tile") {
        let mut pm = pm_base.clone();
        pm.tile = Some(TileStage::for_accel(cfg.clone()));
        pm.alloc = Some(AllocStage::for_accel(cfg.clone()));
        let rep = pm.run(g.clone()).map_err(|e| e.to_string())?;
        let plan = rep.plan.as_ref().expect("alloc stage ran");
        let mut tr = polymem::accel::Trace::new(0);
        let traced = traced_mode == "tiled";
        let sim = simulate_pipelined(&rep.program, plan, cfg, traced.then_some(&mut tr))
            .map_err(|e| e.to_string())?;
        let ts = rep.tile.expect("tile stage ran");
        let mut extras = vec![("tile_stats", ts.to_json()), ("plan", plan.to_json())];
        if traced {
            extras.push((
                "attribution",
                report::attribution_json(&rep.program.graph, tr.attr(), top),
            ));
            attr_table = Some(report::attribution_table(&rep.program.graph, tr.attr(), top));
            write_trace_out(p, &tr)?;
        }
        modes.push(Mode {
            name: "tiled",
            sim,
            extras,
            note: format!(
                "{} groups, {} fused chains, {} staged tensors",
                ts.groups, ts.fused_chains, plan.stats.tile_staged
            ),
        });
    }
    if p.has_flag("opt") {
        let mut pm = pm_base.clone();
        let mut stage = OptStage::for_accel(cfg.clone());
        // 0 keeps the auto default (POLYMEM_SEARCH_THREADS, else cores)
        stage.opts.threads = p.get_usize("search-threads").unwrap_or(0);
        pm.opt = Some(stage);
        pm.alloc = Some(AllocStage::for_accel(cfg.clone()));
        let rep = pm.run(g).map_err(|e| e.to_string())?;
        let plan = rep.plan.as_ref().expect("alloc stage ran");
        let mut tr = polymem::accel::Trace::new(0);
        let sim = simulate_pipelined(&rep.program, plan, cfg, Some(&mut tr))
            .map_err(|e| e.to_string())?;
        let os = rep.opt.expect("opt stage ran");
        let mut extras = vec![("opt_stats", os.to_json()), ("plan", plan.to_json())];
        if let Some(ts) = &rep.tile {
            extras.push(("tile_stats", ts.to_json()));
        }
        extras.push((
            "attribution",
            report::attribution_json(&rep.program.graph, tr.attr(), top),
        ));
        attr_table = Some(report::attribution_table(&rep.program.graph, tr.attr(), top));
        write_trace_out(p, &tr)?;
        modes.push(Mode {
            name: "opt",
            sim,
            extras,
            note: format!("{} candidates, chose {}", os.candidates, os.decision),
        });
    }

    let model = p.get("model");
    if p.has_flag("json") {
        let mut j = report::compare_json(
            model,
            cfg.to_json(),
            modes
                .into_iter()
                .map(|m| (m.name, report::mode_json(&m.sim, m.extras)))
                .collect(),
        );
        if p.has_flag("profile") {
            if let Json::Obj(m) = &mut j {
                m.insert("obs".to_string(), polymem::obs::global().snapshot().to_json());
            }
        }
        println!("{}", j.to_string_pretty());
        return Ok(());
    }
    println!("compiled-mode comparison on '{model}' ({}):\n", cfg.name);
    let pairs: Vec<(&str, &SimReport)> =
        modes.iter().map(|m| (m.name, &m.sim)).collect();
    println!("{}", report::compare_table(model, &pairs));
    for m in &modes {
        println!("  {:<8} {}", m.name, m.note);
    }
    let baseline = modes[0].sim.offchip_total();
    for m in &modes[1..] {
        println!(
            "off-chip reduction ({} vs dynamic): {:.1}%",
            m.name,
            report::pct_reduction(baseline, m.sim.offchip_total())
        );
    }
    if let Some(t) = &attr_table {
        println!("\nper-layer off-chip attribution ({traced_mode}, top {top}):");
        println!("{t}");
    }
    if p.has_flag("profile") {
        println!("compiler telemetry:");
        print!("{}", polymem::obs::global().snapshot().render_text());
    }
    Ok(())
}

fn cmd_export_graph(p: &Parsed) -> Result<(), String> {
    let batch = p.get_usize("batch")? as i64;
    let g = model_by_name(p.get("model"), batch)?;
    let j = polymem::ir::serde::graph_to_json(&g);
    std::fs::write(p.get("out"), j.to_string_pretty())
        .map_err(|e| format!("writing {}: {e}", p.get("out")))?;
    println!(
        "wrote {} ({} nodes, {} tensors)",
        p.get("out"),
        g.nodes().len(),
        g.tensors().count()
    );
    Ok(())
}

fn cmd_e1(_p: &Parsed) -> Result<(), String> {
    let cfg = AccelConfig::inferentia_like();
    let g = polymem::models::parallel_wavenet();
    let before_prog = polymem::ir::Program::lower(g.clone());
    let before = simulate(&before_prog, &cfg, None);
    let mut prog = polymem::ir::Program::lower(g);
    let stats = polymem::passes::dme::run_dme(&mut prog);
    let after = simulate(&prog, &cfg, None);
    println!("E1 — data-movement elimination on Parallel WaveNet\n");
    println!("{}", report::e1_table(&stats, &before, &after));
    Ok(())
}

fn cmd_e2(p: &Parsed) -> Result<(), String> {
    let batch = p.get_usize("batch")? as i64;
    let cfg = accel_from_args(p)?;
    let mut results = vec![];
    for mode in [BankMode::Local, BankMode::Global] {
        let pm = PassManager { bank_mode: mode, ..Default::default() };
        let rep = pm.run(polymem::models::resnet50(batch)).map_err(|e| e.to_string())?;
        let sim = simulate(&rep.program, &cfg, None);
        results.push((rep.bank.unwrap().stats, sim));
    }
    println!("E2 — global vs local bank mapping on ResNet-50 (batch {batch})\n");
    println!(
        "{}",
        report::e2_table(&results[0].0, &results[1].0, &results[0].1, &results[1].1)
    );
    Ok(())
}

fn cmd_serve(p: &Parsed) -> Result<(), String> {
    let artifact = p.get("artifact").to_string();
    let batch = p.get_usize("batch")?;
    let requests = p.get_usize("requests")?;
    let side = p.get_usize("image-side")? as i64;
    let channels = p.get_usize("channels")? as i64;
    let classes = p.get_usize("classes")?;
    let in_shape = vec![channels, side, side];
    let cfg = ServerConfig {
        max_batch: batch,
        max_wait: Duration::from_millis(p.get_u64("max-wait-ms")?),
        queue_cap: 4096,
        ..Default::default()
    };
    let in_shape2 = in_shape.clone();
    let srv = Server::start_with(
        move || {
            let rt = RuntimeClient::cpu()?;
            println!("PJRT platform: {} ({} devices)", rt.platform(), rt.device_count());
            let model = rt.load_hlo_text(std::path::Path::new(&artifact))?;
            Ok(PjrtBackend::new(model, batch, &in_shape2, classes))
        },
        cfg,
    )
    .map_err(|e| e.to_string())?;

    let in_len: i64 = in_shape.iter().product();
    let mut rng = polymem::util::rng::SplitMix64::new(7);
    let t0 = Instant::now();
    let handles: Vec<_> = (0..requests)
        .map(|_| {
            let input: Vec<f32> =
                (0..in_len).map(|_| rng.next_f64() as f32).collect();
            srv.submit(input).map_err(|e| e.to_string())
        })
        .collect::<Result<_, _>>()?;
    let mut checksum = 0f64;
    for h in handles {
        let out = h.wait().map_err(|e| e.to_string())?;
        checksum += out.iter().map(|v| *v as f64).sum::<f64>();
    }
    let elapsed = t0.elapsed();
    let snap = srv.metrics().snapshot();
    println!(
        "served {requests} requests in {elapsed:?} ({:.1} req/s)",
        requests as f64 / elapsed.as_secs_f64()
    );
    println!(
        "latency mean {:?} p50 {:?} p99 {:?}; mean batch {:.2}; checksum {checksum:.4}",
        snap.mean_latency, snap.p50_latency, snap.p99_latency, snap.mean_batch
    );
    srv.shutdown();
    Ok(())
}

fn cmd_bench_regress(p: &Parsed) -> Result<(), String> {
    use polymem::util::regress::{compare, RegressOptions};
    let baseline_path = p.get("baseline");
    let current_path = p.get("current");
    let current_text = std::fs::read_to_string(current_path)
        .map_err(|e| format!("reading current run {current_path}: {e}"))?;
    let current = polymem::util::json::parse(&current_text)
        .map_err(|e| format!("parsing {current_path}: {e}"))?;
    let baseline_text = match std::fs::read_to_string(baseline_path) {
        Ok(t) => t,
        Err(_) if p.has_flag("seed-missing") => {
            // first run on a fresh checkout: adopt the current results
            // as the committed baseline and pass
            if let Some(dir) = std::path::Path::new(baseline_path).parent() {
                std::fs::create_dir_all(dir)
                    .map_err(|e| format!("creating {}: {e}", dir.display()))?;
            }
            std::fs::write(baseline_path, &current_text)
                .map_err(|e| format!("seeding {baseline_path}: {e}"))?;
            println!("seeded baseline {baseline_path} from {current_path}");
            return Ok(());
        }
        Err(e) => return Err(format!("reading baseline {baseline_path}: {e}")),
    };
    let baseline = polymem::util::json::parse(&baseline_text)
        .map_err(|e| format!("parsing {baseline_path}: {e}"))?;
    let opts = RegressOptions {
        rel_tol: p.get_f64("tol")?,
        skip: p
            .get("skip")
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect(),
    };
    let rep = compare(&baseline, &current, &opts);
    print!(
        "bench-regress: {current_path} vs baseline {baseline_path} (tol {:.0}%)\n{}",
        opts.rel_tol * 100.0,
        rep.render()
    );
    if rep.passed() {
        Ok(())
    } else {
        Err(format!(
            "{} metric(s) regressed past the {:.0}% tolerance, {} missing",
            rep.regressions().len(),
            opts.rel_tol * 100.0,
            rep.missing.len()
        ))
    }
}

fn app() -> App {
    App {
        name: "polymem",
        about: "polyhedral memory-access optimization for DL accelerators (Zheng et al. 2020 reproduction)",
        commands: vec![
            Command::new("compile", "run the pass pipeline on a model")
                .opt("model", "resnet50", "model name")
                .opt("graph", "", "JSON graph file (overrides --model)")
                .opt("batch", "1", "batch size")
                .opt("bank-mode", "global", "none|local|global")
                .flag("no-dme", "disable data-movement elimination")
                .flag("no-verify", "skip inter-pass verification"),
            Command::new("simulate", "compile then replay on the accelerator model")
                .opt("model", "resnet50", "model name")
                .opt("graph", "", "JSON graph file (overrides --model)")
                .opt("batch", "1", "batch size")
                .opt("bank-mode", "global", "none|local|global")
                .opt("banks", "0", "override bank count (0 = default)")
                .opt("scratchpad-kib", "0", "override total scratchpad KiB (0 = default)")
                .opt("accel-config", "", "JSON accelerator config path")
                .opt(
                    "cores",
                    "0",
                    "accelerator cores (0 = config default; >1 runs the \
                     pipeline-parallel shard search)",
                )
                .opt("top-layers", "8", "per-layer attribution rows to print")
                .opt("trace-out", "", "write the engine timeline as Chrome trace-event JSON")
                .opt("trace-batches", "4", "batches in the --cores trace timeline")
                .opt(
                    "serve-trace-out",
                    "",
                    "run a traced serving load-sim over the model's buckets and write \
                     request span chains as Chrome trace-event JSON",
                )
                .opt("serve-buckets", "1,2,4,8", "bucket batch sizes for --serve-trace-out")
                .opt("serve-requests", "512", "simulated requests for --serve-trace-out")
                .opt(
                    "search-threads",
                    "0",
                    "joint-search worker threads for --opt \
                     (0 = auto: POLYMEM_SEARCH_THREADS, else all cores)",
                )
                .flag("no-dme", "disable data-movement elimination")
                .flag("no-verify", "skip inter-pass verification")
                .flag("plan", "add the static-plan replay to the comparison")
                .flag("tile", "add the tiled double-buffer pipeline to the comparison")
                .flag("opt", "add the whole-model joint optimizer to the comparison")
                .flag("profile", "collect and print compiler phase/search telemetry")
                .flag("json", "machine-readable output"),
            Command::new("e1", "reproduce paper experiment 1 (WaveNet DME)"),
            Command::new("export-graph", "write a built-in model as a JSON graph")
                .opt("model", "resnet50", "model name")
                .opt("batch", "1", "batch size")
                .req("out", "output JSON path"),
            Command::new("e2", "reproduce paper experiment 2 (ResNet-50 bank mapping)")
                .opt("batch", "1", "batch size")
                .opt("banks", "0", "override bank count (0 = default)")
                .opt("scratchpad-kib", "0", "override total scratchpad KiB (0 = default)")
                .opt("accel-config", "", "JSON accelerator config path")
                .opt("cores", "0", "accelerator cores (0 = config default)"),
            Command::new("serve", "serve an AOT artifact with dynamic batching")
                .opt("artifact", "artifacts/model.hlo.txt", "HLO text artifact")
                .opt("batch", "8", "compiled batch size")
                .opt("requests", "256", "synthetic requests to send")
                .opt("image-side", "32", "input H=W")
                .opt("channels", "3", "input channels")
                .opt("classes", "10", "output classes")
                .opt("max-wait-ms", "2", "batching deadline"),
            Command::new("bench-regress", "gate a benchmark JSON record against a baseline")
                .req("baseline", "committed baseline JSON path")
                .req("current", "freshly produced benchmark JSON path")
                .opt("tol", "0.15", "allowed relative regression per gated metric")
                .opt("skip", "", "comma-separated path substrings to exclude")
                .flag("seed-missing", "adopt the current run as baseline when none exists"),
        ],
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let app = app();
    let (cmd, parsed) = match app.dispatch(&argv) {
        Ok(x) => x,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let result = match cmd.name {
        "compile" => cmd_compile(&parsed),
        "simulate" => cmd_simulate(&parsed),
        "e1" => cmd_e1(&parsed),
        "export-graph" => cmd_export_graph(&parsed),
        "e2" => cmd_e2(&parsed),
        "serve" => cmd_serve(&parsed),
        "bench-regress" => cmd_bench_regress(&parsed),
        _ => unreachable!(),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
