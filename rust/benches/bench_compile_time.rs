//! Compile-time scaling: the optimizer must stay a negligible part of
//! a production toolchain run across every model in the zoo.
//!
//! Also the compile-telemetry artifact: emits per-model pass-phase
//! wall times and one joint-search profile (generations, best-cost
//! trajectory, candidates/second) to
//! `$BENCH_JSON_DIR/BENCH_compile_phases.json` (ci.sh collects it).
//!
//! Run: `cargo bench --bench bench_compile_time`

use polymem::accel::AccelConfig;
use polymem::ir::Graph;
use polymem::passes::manager::{AllocStage, OptStage, PassManager};
use polymem::util::bench::{black_box, write_json_record, Bench, Suite};
use polymem::util::json::Json;

fn zoo() -> Vec<(&'static str, Box<dyn Fn() -> Graph>)> {
    vec![
        ("mlp", Box::new(|| polymem::models::mlp(8, 784, 512, 10, 4))),
        ("transformer", Box::new(|| polymem::models::transformer_block(128, 256, 8, 1024))),
        ("resnet18", Box::new(|| polymem::models::resnet18(1))),
        ("resnet50", Box::new(|| polymem::models::resnet50(1))),
        ("wavenet", Box::new(polymem::models::parallel_wavenet)),
    ]
}

/// The 2 MiB configuration (inferentia-like geometry, banks shrunk).
fn two_mib() -> AccelConfig {
    let mut cfg = AccelConfig::inferentia_like();
    cfg.bank_bytes /= 4; // 8 MiB -> 2 MiB
    cfg.name = "inferentia-like/4".into();
    cfg
}

fn main() {
    let mut suite = Suite::new("compile-time scaling (full pipeline: lower + DME + global bank mapping)");
    let mut model_records: Vec<Json> = Vec::new();
    for (name, build) in zoo() {
        let nodes = build().nodes().len();
        let stats = Bench::new(format!("{name} ({nodes} nodes)"))
            .samples(10)
            .throughput_items(nodes as f64)
            .run(|| {
                let pm = PassManager::default();
                black_box(pm.run(build()).unwrap())
            });
        // one instrumented run for the per-phase wall-time record
        let rep = PassManager::default().run(build()).unwrap();
        model_records.push(Json::obj(vec![
            ("model", Json::Str(name.to_string())),
            ("nodes", Json::Int(nodes as i64)),
            ("mean_seconds", Json::Num(stats.mean.as_secs_f64())),
            (
                "phases",
                Json::Arr(rep.phases.iter().map(|p| p.to_json()).collect()),
            ),
        ]));
        suite.add(stats);
    }

    // pass-phase breakdown on the largest model
    println!("\nphase breakdown on resnet50:");
    let pm = PassManager::default();
    let rep = pm.run(polymem::models::resnet50(1)).unwrap();
    for p in &rep.phases {
        println!("  {:<6} {:.6}s", p.name, p.seconds);
    }

    // joint-search profile: beam generations + throughput on a model
    // that actually searches (mobilenet feature maps overflow 2 MiB)
    println!("\njoint-search profile (mobilenet @ 2 MiB):");
    let cfg = two_mib();
    let pm = PassManager {
        opt: Some(OptStage::for_accel(cfg.clone())),
        alloc: Some(AllocStage::for_accel(cfg.clone())),
        ..Default::default()
    };
    let orep = pm.run(polymem::models::mobilenet_v1(1)).unwrap();
    let os = orep.opt.expect("opt stage ran");
    for g in &os.generations {
        println!(
            "  {:<5} axis: {} generated, {} realized, {} pruned, best {}",
            g.axis,
            g.generated,
            g.realized,
            g.pruned,
            polymem::report::mb(g.best_offchip)
        );
    }
    let cps = os.candidates as f64 / os.search_seconds.max(1e-9);
    println!(
        "  search: {} candidates in {:.3}s ({cps:.1} candidates/s)",
        os.candidates, os.search_seconds
    );
    let opt_profile = Json::obj(vec![
        ("model", Json::Str("mobilenet".to_string())),
        ("accel", cfg.to_json()),
        ("opt_stats", os.to_json()),
        (
            "phases",
            Json::Arr(orep.phases.iter().map(|p| p.to_json()).collect()),
        ),
        ("candidates_per_second", Json::Num(cps)),
    ]);

    write_json_record(
        "BENCH_compile_phases.json",
        &Json::obj(vec![
            ("models", Json::Arr(model_records)),
            ("opt_profile", opt_profile),
        ]),
    );

    // verification cost
    let mut suite2 = Suite::new("verification overhead (resnet50)");
    for verify in [true, false] {
        suite2.add(
            Bench::new(if verify { "verify on" } else { "verify off" })
                .samples(8)
                .run(|| {
                    let pm = PassManager { verify, ..Default::default() };
                    black_box(pm.run(polymem::models::resnet50(1)).unwrap())
                }),
        );
    }
    suite2.finish();
    suite.finish();
}
