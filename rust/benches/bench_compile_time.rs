//! Compile-time scaling: the optimizer must stay a negligible part of
//! a production toolchain run across every model in the zoo.
//!
//! Also the compile-telemetry artifact: emits per-model pass-phase
//! wall times, one joint-search profile (generations, best-cost
//! trajectory, candidates/second) and the beam-width sweep — search
//! throughput at widths {3, 8, 16} against the pre-memoization
//! full-serial realization path — to
//! `$BENCH_JSON_DIR/BENCH_compile_phases.json` (ci.sh collects it and
//! gates it against `BENCH_baseline/`).
//!
//! Run: `cargo bench --bench bench_compile_time`

use polymem::accel::AccelConfig;
use polymem::alloc::AllocOpts;
use polymem::ir::loopnest::Program;
use polymem::ir::Graph;
use polymem::opt::{realize_full, search, OptOpts};
use polymem::passes::manager::{AllocStage, BankMode, OptStage, PassManager};
use polymem::passes::{run_dme, BankConfig};
use polymem::tile::TileOpts;
use polymem::util::bench::{black_box, write_json_record, Bench, Suite};
use polymem::util::json::Json;
use std::time::Instant;

fn zoo() -> Vec<(&'static str, Box<dyn Fn() -> Graph>)> {
    vec![
        ("mlp", Box::new(|| polymem::models::mlp(8, 784, 512, 10, 4))),
        ("transformer", Box::new(|| polymem::models::transformer_block(128, 256, 8, 1024))),
        ("resnet18", Box::new(|| polymem::models::resnet18(1))),
        ("resnet50", Box::new(|| polymem::models::resnet50(1))),
        ("wavenet", Box::new(polymem::models::parallel_wavenet)),
    ]
}

/// The 2 MiB configuration (inferentia-like geometry, banks shrunk).
fn two_mib() -> AccelConfig {
    let mut cfg = AccelConfig::inferentia_like();
    cfg.bank_bytes /= 4; // 8 MiB -> 2 MiB
    cfg.name = "inferentia-like/4".into();
    cfg
}

fn main() {
    let mut suite = Suite::new("compile-time scaling (full pipeline: lower + DME + global bank mapping)");
    let mut model_records: Vec<Json> = Vec::new();
    let mut resnet50_phases: Vec<polymem::obs::PhaseSample> = Vec::new();
    for (name, build) in zoo() {
        let nodes = build().nodes().len();
        // every sample is instrumented (PassReport always carries phase
        // times); the last sample's report doubles as the phase record,
        // so the old separate phase-record run is gone
        let mut last = None;
        let stats = Bench::new(format!("{name} ({nodes} nodes)"))
            .samples(10)
            .throughput_items(nodes as f64)
            .run(|| {
                last = Some(PassManager::default().run(build()).unwrap());
            });
        let rep = last.expect("bench ran at least one sample");
        if name == "resnet50" {
            resnet50_phases = rep.phases.clone();
        }
        model_records.push(Json::obj(vec![
            ("label", Json::Str(name.to_string())),
            ("model", Json::Str(name.to_string())),
            ("nodes", Json::Int(nodes as i64)),
            ("mean_seconds", Json::Num(stats.mean.as_secs_f64())),
            (
                "phases",
                Json::Arr(rep.phases.iter().map(|p| p.to_json()).collect()),
            ),
        ]));
        suite.add(stats);
    }

    // pass-phase breakdown on the largest model (reused from the
    // sample loop, not a fresh pipeline run)
    println!("\nphase breakdown on resnet50:");
    for p in &resnet50_phases {
        println!("  {:<6} {:.6}s", p.name, p.seconds);
    }

    // joint-search profile: beam generations + throughput on a model
    // that actually searches (mobilenet feature maps overflow 2 MiB)
    println!("\njoint-search profile (mobilenet @ 2 MiB):");
    let cfg = two_mib();
    let pm = PassManager {
        opt: Some(OptStage::for_accel(cfg.clone())),
        alloc: Some(AllocStage::for_accel(cfg.clone())),
        ..Default::default()
    };
    let orep = pm.run(polymem::models::mobilenet_v1(1)).unwrap();
    let os = orep.opt.expect("opt stage ran");
    for g in &os.generations {
        println!(
            "  {:<5} axis: {} generated, {} realized, {} pruned, best {}",
            g.axis,
            g.generated,
            g.realized,
            g.pruned,
            polymem::report::mb(g.best_offchip)
        );
    }
    let cps = os.candidates as f64 / os.search_seconds.max(1e-9);
    println!(
        "  search: {} candidates in {:.3}s ({cps:.1} candidates/s, {} threads)",
        os.candidates, os.search_seconds, os.threads
    );
    let opt_profile = Json::obj(vec![
        ("model", Json::Str("mobilenet".to_string())),
        ("accel", cfg.to_json()),
        ("opt_stats", os.to_json()),
        (
            "phases",
            Json::Arr(orep.phases.iter().map(|p| p.to_json()).collect()),
        ),
        ("candidates_per_second", Json::Num(cps)),
    ]);

    // beam-width sweep: the incremental+parallel search vs the
    // pre-memoization reference on the acceptance workload. For each
    // width the exact audited candidate set is re-realized from
    // scratch, serially, through the unshared tile → bank → splice →
    // plan path (`realize_full`) — which both times the old cost per
    // candidate honestly and live-checks the calibration contract.
    println!("\njoint-search beam sweep (resnet50 @ 2 MiB):");
    let prog = {
        let mut p = Program::lower(polymem::models::resnet50(1));
        run_dme(&mut p);
        p
    };
    let mut sweep_rows: Vec<Json> = Vec::new();
    for width in [3usize, 8, 16] {
        let opts = OptOpts { beam_width: width, threads: 0 };
        let t0 = Instant::now();
        let out = search(
            &prog,
            BankMode::Global,
            &BankConfig::default(),
            &cfg,
            &TileOpts::default(),
            &AllocOpts::default(),
            &opts,
        )
        .unwrap();
        let search_wall = t0.elapsed().as_secs_f64();
        let cand_per_s = out.stats.candidates as f64 / search_wall.max(1e-9);
        let t1 = Instant::now();
        for (dv, cost) in &out.audit {
            let full = realize_full(
                &prog,
                *dv,
                BankMode::Global,
                &BankConfig::default(),
                &cfg,
                &TileOpts::default(),
                &AllocOpts::default(),
            )
            .unwrap();
            assert!(
                full.bits_eq(cost),
                "calibration violated at beam {width}: {}",
                dv.describe()
            );
            black_box(full);
        }
        let serial_wall = t1.elapsed().as_secs_f64();
        let serial_per_s = out.audit.len() as f64 / serial_wall.max(1e-9);
        let speedup = serial_wall / search_wall.max(1e-9);
        println!(
            "  beam {width:>2}: {} candidates | incremental {cand_per_s:>8.1} cand/s \
             ({} threads) | full-serial {serial_per_s:>8.1} cand/s | speedup {speedup:>5.1}x \
             | best {} via {}",
            out.stats.candidates,
            out.stats.threads,
            polymem::report::mb(out.stats.best_offchip),
            out.stats.decision
        );
        sweep_rows.push(Json::obj(vec![
            ("label", Json::Str(format!("beam{width}"))),
            ("beam_width", Json::Int(width as i64)),
            ("threads", Json::Int(out.stats.threads as i64)),
            ("candidates", Json::Int(out.stats.candidates as i64)),
            ("pruned", Json::Int(out.stats.pruned as i64)),
            ("search_wall_seconds", Json::Num(search_wall)),
            ("candidates_per_second", Json::Num(cand_per_s)),
            ("full_serial_wall_seconds", Json::Num(serial_wall)),
            ("full_serial_candidates_per_second", Json::Num(serial_per_s)),
            ("speedup_vs_full_serial", Json::Num(speedup)),
            ("best_offchip", Json::Int(out.stats.best_offchip)),
            ("decision", Json::Str(out.stats.decision.clone())),
        ]));
    }
    let beam_sweep = Json::obj(vec![
        ("model", Json::Str("resnet50".to_string())),
        ("accel", cfg.to_json()),
        ("widths", Json::Arr(sweep_rows)),
    ]);

    write_json_record(
        "BENCH_compile_phases.json",
        &Json::obj(vec![
            ("models", Json::Arr(model_records)),
            ("opt_profile", opt_profile),
            ("beam_sweep", beam_sweep),
        ]),
    );

    // verification cost
    let mut suite2 = Suite::new("verification overhead (resnet50)");
    for verify in [true, false] {
        suite2.add(
            Bench::new(if verify { "verify on" } else { "verify off" })
                .samples(8)
                .run(|| {
                    let pm = PassManager { verify, ..Default::default() };
                    black_box(pm.run(polymem::models::resnet50(1)).unwrap())
                }),
        );
    }
    suite2.finish();
    suite.finish();
}
