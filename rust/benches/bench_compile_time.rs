//! Compile-time scaling: the optimizer must stay a negligible part of
//! a production toolchain run across every model in the zoo.
//!
//! Run: `cargo bench --bench bench_compile_time`

use polymem::ir::Graph;
use polymem::passes::manager::{BankMode, PassManager};
use polymem::util::bench::{black_box, Bench, Suite};

fn zoo() -> Vec<(&'static str, Box<dyn Fn() -> Graph>)> {
    vec![
        ("mlp", Box::new(|| polymem::models::mlp(8, 784, 512, 10, 4))),
        ("transformer", Box::new(|| polymem::models::transformer_block(128, 256, 8, 1024))),
        ("resnet18", Box::new(|| polymem::models::resnet18(1))),
        ("resnet50", Box::new(|| polymem::models::resnet50(1))),
        ("wavenet", Box::new(polymem::models::parallel_wavenet)),
    ]
}

fn main() {
    let mut suite = Suite::new("compile-time scaling (full pipeline: lower + DME + global bank mapping)");
    for (name, build) in zoo() {
        let nodes = build().nodes().len();
        suite.add(
            Bench::new(format!("{name} ({nodes} nodes)"))
                .samples(10)
                .throughput_items(nodes as f64)
                .run(|| {
                    let pm = PassManager::default();
                    black_box(pm.run(build()).unwrap())
                }),
        );
    }

    // pass-phase breakdown on the largest model
    println!("\nphase breakdown on resnet50:");
    let pm = PassManager::default();
    let rep = pm.run(polymem::models::resnet50(1)).unwrap();
    println!("  dme:  {:?}", rep.dme_time);
    println!("  bank: {:?}", rep.bank_time);

    // verification cost
    let mut suite2 = Suite::new("verification overhead (resnet50)");
    for verify in [true, false] {
        suite2.add(
            Bench::new(if verify { "verify on" } else { "verify off" })
                .samples(8)
                .run(|| {
                    let pm = PassManager { verify, ..Default::default() };
                    black_box(pm.run(polymem::models::resnet50(1)).unwrap())
                }),
        );
    }
    suite2.finish();
    suite.finish();
}
