//! Bench E3: planned vs dynamic scratchpad residency.
//!
//! The static planner (`alloc`) must never lose to the simulator's
//! replay-time Belady residency on off-chip bytes — it has strictly
//! more information (whole-schedule liveness, explicit spill
//! placement, min-footprint scheduling). This bench runs both modes on
//! ResNet-50 and Parallel WaveNet, prints the comparison table, emits
//! one machine-readable JSON record per model (same `sim_to_json`
//! shape as the other benches), and asserts the acceptance relation
//! `planned off-chip <= dynamic off-chip`.
//!
//! Run: `cargo bench --bench bench_alloc_plan`

use polymem::accel::{simulate, simulate_planned, AccelConfig, SimReport};
use polymem::alloc::MemoryPlan;
use polymem::ir::Graph;
use polymem::passes::manager::{AllocStage, PassManager};
use polymem::report;
use polymem::util::bench::{black_box, write_json_record, Bench, Suite};
use polymem::util::json::Json;

fn models() -> Vec<(&'static str, Graph)> {
    vec![
        ("resnet50", polymem::models::resnet50(1)),
        ("wavenet", polymem::models::parallel_wavenet()),
    ]
}

fn run_pair(g: Graph, cfg: &AccelConfig) -> (SimReport, SimReport, MemoryPlan) {
    // dynamic baseline: the standard pipeline, residency improvised at
    // replay time
    let base = PassManager::default().run(g.clone()).expect("baseline pipeline");
    let dynamic = simulate(&base.program, cfg, None);
    // planned: same pipeline plus the alloc stage, residency replayed
    // from the verified MemoryPlan
    let pm = PassManager {
        alloc: Some(AllocStage::for_accel(cfg.clone())),
        ..Default::default()
    };
    let rep = pm.run(g).expect("planned pipeline");
    let plan = rep.plan.expect("alloc stage ran");
    let planned = simulate_planned(&rep.program, &plan, cfg, None)
        .expect("plan verifies with zero violations");
    (dynamic, planned, plan)
}

fn main() {
    let cfg = AccelConfig::inferentia_like();

    println!("\nE3 — planned vs dynamic scratchpad residency\n");
    let mut records: Vec<Json> = Vec::new();
    for (name, g) in models() {
        let (dynamic, planned, plan) = run_pair(g, &cfg);
        println!("{}", report::e3_table(name, &dynamic, &planned, &plan));
        let record = report::planned_vs_dynamic_json(name, &dynamic, &planned, &plan);
        println!("{}", record.to_string_compact());
        records.push(record);
        println!();
        assert!(
            planned.offchip_total() <= dynamic.offchip_total(),
            "{name}: planned off-chip {} > dynamic {}",
            planned.offchip_total(),
            dynamic.offchip_total()
        );
        assert!(
            planned.peak_scratchpad <= cfg.scratchpad_bytes(),
            "{name}: plan exceeds configured SRAM"
        );
    }
    write_json_record("BENCH_plan.json", &Json::Arr(records));

    // constrained-capacity series: how both modes degrade when the
    // scratchpad shrinks (no ordering assertion here — the planner
    // honors bank granularity the group-blind baseline ignores)
    println!("capacity scaling on ResNet-50 (off-chip MB, dynamic vs planned):\n");
    let mut t = report::Table::new(&["scratchpad", "dynamic", "planned", "spill pairs"]);
    for shrink in [1i64, 2, 4] {
        let mut c = AccelConfig::inferentia_like();
        c.bank_bytes /= shrink;
        let (dynamic, planned, plan) = run_pair(polymem::models::resnet50(1), &c);
        t.row(&[
            report::mb(c.scratchpad_bytes()),
            report::mb(dynamic.offchip_total()),
            report::mb(planned.offchip_total()),
            plan.stats.spill_pairs.to_string(),
        ]);
    }
    println!("{}", t.render());

    // ---- timing ----
    let mut suite = Suite::new("E3 timing");
    let g = polymem::models::resnet50(1);
    suite.add(Bench::new("plan_memory(resnet50)").samples(5).run(|| {
        let pm = PassManager {
            alloc: Some(AllocStage::for_accel(cfg.clone())),
            verify: false,
            ..Default::default()
        };
        black_box(pm.run(g.clone()).unwrap())
    }));
    let pm = PassManager {
        alloc: Some(AllocStage::for_accel(cfg.clone())),
        ..Default::default()
    };
    let rep = pm.run(polymem::models::resnet50(1)).unwrap();
    let plan = rep.plan.unwrap();
    suite.add(
        Bench::new("simulate_planned(resnet50)")
            .samples(10)
            .run(|| black_box(simulate_planned(&rep.program, &plan, &cfg, None).unwrap())),
    );
    suite.add(
        Bench::new("simulate_dynamic(resnet50)")
            .samples(10)
            .run(|| black_box(simulate(&rep.program, &cfg, None))),
    );
    suite.finish();
}
