//! Bench E4: tiled double-buffer pipeline vs untiled planning.
//!
//! The acceptance scenario of `tile/`: a chip whose scratchpad is
//! smaller than ResNet-50's largest intermediate (2 MiB against
//! conv1's 3.2 MB feature map). The untiled planner must stream every
//! oversized intermediate through DRAM; the tiled pipeline stages them
//! through double-buffered regions and must report **strictly fewer
//! off-chip bytes**, plus an honest pipelined latency instead of the
//! per-nest `max(compute, dma)` estimate.
//!
//! Emits one machine-readable record per scenario to
//! `$BENCH_JSON_DIR/BENCH_tile.json` (ci.sh collects it).
//!
//! Run: `cargo bench --bench bench_tile`

use polymem::accel::{simulate_pipelined, simulate_planned, AccelConfig, SimReport};
use polymem::ir::Graph;
use polymem::passes::manager::{AllocStage, PassManager, TileStage};
use polymem::report;
use polymem::util::bench::{black_box, write_json_record, Bench, Suite};
use polymem::util::json::Json;

fn cramped(shrink: i64) -> AccelConfig {
    let mut cfg = AccelConfig::inferentia_like();
    cfg.bank_bytes /= shrink;
    cfg.name = format!("inferentia-like/{shrink}");
    cfg
}

struct Row {
    untiled: SimReport,
    tiled: SimReport,
    tile_stats: polymem::tile::TileStats,
    plan_stats: polymem::alloc::PlanStats,
}

fn run_pair(g: Graph, cfg: &AccelConfig) -> Row {
    let untiled_pm = PassManager {
        alloc: Some(AllocStage::for_accel(cfg.clone())),
        ..Default::default()
    };
    let urep = untiled_pm.run(g.clone()).expect("untiled pipeline");
    let untiled = simulate_planned(
        &urep.program,
        urep.plan.as_ref().expect("plan"),
        cfg,
        None,
    )
    .expect("untiled plan verifies");

    let tiled_pm = PassManager {
        tile: Some(TileStage::for_accel(cfg.clone())),
        alloc: Some(AllocStage::for_accel(cfg.clone())),
        ..Default::default()
    };
    let trep = tiled_pm.run(g).expect("tiled pipeline");
    let plan = trep.plan.as_ref().expect("plan");
    let tiled = simulate_pipelined(&trep.program, plan, cfg, None)
        .expect("tiled plan verifies");
    Row {
        untiled,
        tiled,
        tile_stats: trep.tile.expect("tile stage ran"),
        plan_stats: plan.stats,
    }
}

fn main() {
    println!("\nE4 — tiled double-buffer pipeline vs untiled planning (ResNet-50)\n");
    let mut records: Vec<Json> = Vec::new();
    let mut table = report::Table::new(&[
        "scratchpad",
        "untiled off-chip",
        "tiled off-chip",
        "groups",
        "staged",
        "untiled ms",
        "tiled ms",
    ]);
    for shrink in [4i64, 8] {
        let cfg = cramped(shrink);
        let row = run_pair(polymem::models::resnet50(1), &cfg);
        assert!(
            row.tiled.offchip_total() < row.untiled.offchip_total(),
            "{}: tiled off-chip {} not strictly below untiled {}",
            cfg.name,
            row.tiled.offchip_total(),
            row.untiled.offchip_total()
        );
        assert!(row.tile_stats.fused_chains > 0, "no fused chains");
        assert!(row.plan_stats.tile_staged > 0, "no staged intermediates");
        table.row(&[
            report::mb(cfg.scratchpad_bytes()),
            report::mb(row.untiled.offchip_total()),
            report::mb(row.tiled.offchip_total()),
            row.tile_stats.groups.to_string(),
            row.plan_stats.tile_staged.to_string(),
            format!("{:.3}", row.untiled.seconds * 1e3),
            format!("{:.3}", row.tiled.seconds * 1e3),
        ]);
        records.push(Json::obj(vec![
            ("model", Json::Str("resnet50".into())),
            ("accel", cfg.to_json()),
            ("untiled", report::sim_to_json(&row.untiled)),
            ("tiled", report::sim_to_json(&row.tiled)),
            ("tile_stats", row.tile_stats.to_json()),
            (
                "offchip_reduction_pct",
                Json::Num(report::pct_reduction(
                    row.untiled.offchip_total(),
                    row.tiled.offchip_total(),
                )),
            ),
        ]));
    }
    println!("{}", table.render());
    write_json_record("BENCH_tile.json", &Json::Arr(records));

    // ---- timing ----
    let mut suite = Suite::new("E4 timing");
    let cfg = cramped(4);
    let g = polymem::models::resnet50(1);
    suite.add(Bench::new("tile+plan(resnet50)").samples(3).run(|| {
        let pm = PassManager {
            tile: Some(TileStage::for_accel(cfg.clone())),
            alloc: Some(AllocStage::for_accel(cfg.clone())),
            verify: false,
            ..Default::default()
        };
        black_box(pm.run(g.clone()).unwrap())
    }));
    let pm = PassManager {
        tile: Some(TileStage::for_accel(cfg.clone())),
        alloc: Some(AllocStage::for_accel(cfg.clone())),
        ..Default::default()
    };
    let rep = pm.run(polymem::models::resnet50(1)).unwrap();
    let plan = rep.plan.unwrap();
    suite.add(
        Bench::new("simulate_pipelined(resnet50)")
            .samples(5)
            .run(|| black_box(simulate_pipelined(&rep.program, &plan, &cfg, None).unwrap())),
    );
    suite.finish();
}
