//! E7 — multi-core pipeline sharding vs the single-core serving path.
//!
//! 1. Compile ResNet-50 @ the cramped 2 MiB scratchpad twice through
//!    the AOT plan cache: once single-core, once on a 4-core chip —
//!    the multi-core compile attaches a `ShardedPlan` (stage cuts,
//!    per-stage artifacts, fabric bytes, combined cost).
//! 2. Re-verify the sharded calibration from the outside: the
//!    search's predicted `ShardedCost` must be byte-exact on traffic
//!    and bit-exact on seconds against an independent multi-engine
//!    replay of the stage artifacts.
//! 3. Report the amortized-cost placement decision for 4 idle cores
//!    (shard one pipeline vs 4 independent replicas).
//! 4. Load simulation at equal offered load (closed loop, identical
//!    client population): single-core `run_load` baseline vs the
//!    sharded pipeline under `run_load_pipelined`, with the 4-replica
//!    alternative as a reference row. **Acceptance:** the sharded
//!    pipeline sustains strictly higher QPS than the single core.
//!
//! Emits `$BENCH_JSON_DIR/BENCH_multicore.json`.
//!
//! Run: `cargo bench --bench bench_multicore`

use polymem::accel::AccelConfig;
use polymem::coordinator::BucketCost;
use polymem::serve::{
    choose_placement, run_load, run_load_pipelined, Arrivals, LoadReport, LoadSimConfig,
    PipelinedBucket, PlanCache, PlanCacheConfig,
};
use polymem::shard;
use polymem::util::bench::{write_json_record, Suite};
use polymem::util::json::Json;
use std::time::Duration;

const CORES: usize = 4;

/// The 2 MiB configuration (inferentia-like geometry, banks shrunk).
fn two_mib() -> AccelConfig {
    let mut cfg = AccelConfig::inferentia_like();
    cfg.bank_bytes /= 4; // 8 MiB -> 2 MiB
    cfg.name = "inferentia-like/4".into();
    cfg
}

fn print_load(r: &LoadReport) {
    println!(
        "  {:<30} p50 {:?} p99 {:?}, {:>9.0} qps, {:>7.2} KiB/req, \
         mean batch {:.2}, rejected {}",
        r.label,
        r.p50(),
        r.p99(),
        r.qps,
        r.bytes_per_request / 1024.0,
        r.mean_batch,
        r.rejected
    );
}

fn main() {
    let suite = Suite::new("multi-core sharding");

    // ---- 1. plan-cache compiles: 1 core vs 4 cores ----
    let single_accel = two_mib();
    let multi_accel = two_mib().with_cores(CORES);
    println!(
        "\nplan cache: resnet50 b8 @ {} (joint optimizer), 1 vs {} cores:",
        single_accel.name, CORES
    );
    let mut single_cache = PlanCache::new(
        "resnet50",
        PlanCacheConfig { accel: single_accel.clone(), joint: true, verify: false, max_entries: 0 },
    );
    let single = single_cache.get_or_compile(8).expect("single-core compile");
    let mut multi_cache = PlanCache::new(
        "resnet50",
        PlanCacheConfig { accel: multi_accel.clone(), joint: true, verify: false, max_entries: 0 },
    );
    let multi = multi_cache.get_or_compile(8).expect("multi-core compile");
    let plan = multi
        .sharded
        .as_ref()
        .expect("a multi-core plan-cache compile attaches a sharding");

    println!(
        "  single core : service {:>7.3} ms, off-chip {:>8.2} MiB  [{}]",
        single.service_seconds * 1e3,
        single.cost.offchip_total() as f64 / (1 << 20) as f64,
        single.decision
    );
    println!(
        "  {} cores     : {} stage(s), interval {:>7.3} ms, fill latency {:>7.3} ms, \
         off-chip {:>8.2} MiB, fabric {:>7.2} MiB/batch",
        CORES,
        plan.stages.len(),
        plan.interval_seconds() * 1e3,
        plan.latency_seconds() * 1e3,
        plan.cost.offchip_total() as f64 / (1 << 20) as f64,
        plan.cost.traffic.intercore_total() as f64 / (1 << 20) as f64
    );
    println!("    {}", plan.decision);

    // ---- 2. independent calibration check: multi-engine replay ----
    let replay = shard::replay_sharded(&plan.stages, &plan.transfer_bytes, &multi_accel)
        .expect("multi-engine replay");
    assert!(
        plan.cost.bits_eq(&replay),
        "sharded calibration broke: search prediction != multi-engine replay"
    );
    println!("  calibration: traffic byte-exact, seconds bit-exact vs multi-engine replay");

    // the sharding must actually pipeline: steady-state interval
    // strictly under the single-core service time, or the QPS
    // acceptance below cannot hold
    assert!(
        plan.interval_seconds() < single.service_seconds,
        "sharded interval {} >= single-core service {}",
        plan.interval_seconds(),
        single.service_seconds
    );

    // ---- 3. per-core placement decision ----
    let placement = choose_placement(single.service_seconds, plan.interval_seconds(), CORES);
    println!(
        "  placement on {CORES} idle cores: {:?} (sharded interval {:.3} ms vs \
         service/cores {:.3} ms)",
        placement,
        plan.interval_seconds() * 1e3,
        single.service_seconds / CORES as f64 * 1e3
    );

    // ---- 4. equal offered load: single core vs sharded pipeline ----
    let svc = single.service_seconds;
    let single_cost = BucketCost {
        batch: single.batch as usize,
        offchip_bytes: single.cost.offchip_total(),
        service_seconds: svc,
    };
    // the sharded service model: a batch occupies the pipeline head
    // for one interval and completes after the fill latency
    let sharded_bucket = PipelinedBucket {
        cost: BucketCost {
            batch: multi.batch as usize,
            offchip_bytes: plan.cost.offchip_total(),
            service_seconds: plan.latency_seconds(),
        },
        interval_seconds: plan.interval_seconds(),
    };
    let replica_bucket = PipelinedBucket { cost: single_cost, interval_seconds: svc };

    let sim = LoadSimConfig {
        arrivals: Arrivals::Closed { clients: 64, requests: 4000 },
        max_wait: Duration::from_secs_f64(svc * 2.0),
        queue_cap: 256,
        slo: None,
    };
    println!("\nclosed-loop load (64 clients, 4000 requests, identical offered load):");
    let base = run_load(&[single_cost], &sim, "closed-loop / single-core");
    let pipe = run_load_pipelined(&[sharded_bucket], 1, &sim, "closed-loop / sharded-4core");
    let repl = run_load_pipelined(
        &[replica_bucket],
        CORES,
        &sim,
        "closed-loop / replicas-4core",
    );
    print_load(&base);
    print_load(&pipe);
    print_load(&repl);

    assert_eq!(
        base.completed, pipe.completed,
        "offered load diverged between the single-core and sharded runs"
    );
    // the acceptance criterion: at equal offered load, the sharded
    // pipeline sustains strictly higher QPS than one core
    assert!(
        pipe.qps > base.qps,
        "sharding did not raise saturated QPS: {} <= {}",
        pipe.qps,
        base.qps
    );
    let speedup = pipe.qps / base.qps;
    println!(
        "  sharded vs single-core QPS speedup: {speedup:.2}x \
         (replicas reference: {:.2}x)",
        repl.qps / base.qps
    );

    // ---- machine-readable record ----
    let record = Json::obj(vec![
        ("model", Json::Str("resnet50".into())),
        ("cores", Json::Int(CORES as i64)),
        ("accel", multi_accel.to_json()),
        (
            "single_core",
            Json::obj(vec![
                ("batch", Json::Int(single.batch)),
                ("service_seconds", Json::Num(single.service_seconds)),
                ("offchip_bytes", Json::Int(single.cost.offchip_total())),
            ]),
        ),
        ("sharded", plan.to_json()),
        ("placement", Json::Str(format!("{placement:?}"))),
        ("calibration_bits_exact", Json::Int(1)),
        ("loads", Json::Arr(vec![base.to_json(), pipe.to_json(), repl.to_json()])),
        ("sharded_qps_speedup", Json::Num(speedup)),
    ]);
    write_json_record("BENCH_multicore.json", &record);

    suite.finish();
}
