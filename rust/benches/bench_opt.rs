//! Bench E5: whole-model joint optimization vs the staged greedy.
//!
//! The acceptance scenario of `cost/` + `opt/`: on a 2 MiB scratchpad
//! (smaller than ResNet-50's and MobileNet's early feature maps), the
//! joint beam search over fusion / tile-budget / schedule / spill
//! decision vectors must deliver **strictly fewer off-chip bytes**
//! than the staged-greedy pipeline (tile + plan with each pass's local
//! proxy) on both models — the cross-stage trades (conv-chain halo
//! recompute keeping boundary tensors staged, converging-branch
//! fusion) that the independent greedy heuristics are structurally
//! unable to make. Also asserts the calibration invariant on the
//! winning plans: predicted bytes equal simulated bytes exactly.
//!
//! Emits one machine-readable record per model to
//! `$BENCH_JSON_DIR/BENCH_opt.json` (ci.sh collects it).
//!
//! Run: `cargo bench --bench bench_opt`

use polymem::accel::{simulate_pipelined, AccelConfig, SimReport};
use polymem::cost;
use polymem::ir::Graph;
use polymem::passes::manager::{AllocStage, OptStage, PassManager, TileStage};
use polymem::report;
use polymem::util::bench::{black_box, write_json_record, Bench, Suite};
use polymem::util::json::Json;

/// The 2 MiB configuration (inferentia-like geometry, banks shrunk).
fn two_mib() -> AccelConfig {
    let mut cfg = AccelConfig::inferentia_like();
    cfg.bank_bytes /= 4; // 8 MiB -> 2 MiB
    cfg.name = "inferentia-like/4".into();
    cfg
}

struct Row {
    staged: SimReport,
    joint: SimReport,
    opt_stats: polymem::opt::OptStats,
}

fn run_pair(g: Graph, cfg: &AccelConfig) -> Row {
    // staged greedy: the fixed tile stage + planner, every decision
    // scored by its own local proxy
    let staged_pm = PassManager {
        tile: Some(TileStage::for_accel(cfg.clone())),
        alloc: Some(AllocStage::for_accel(cfg.clone())),
        ..Default::default()
    };
    let srep = staged_pm.run(g.clone()).expect("staged pipeline");
    let splan = srep.plan.as_ref().expect("plan");
    let staged =
        simulate_pipelined(&srep.program, splan, cfg, None).expect("staged plan verifies");

    // joint: the beam search over decision vectors, scored by cost/
    let joint_pm = PassManager {
        opt: Some(OptStage::for_accel(cfg.clone())),
        alloc: Some(AllocStage::for_accel(cfg.clone())),
        ..Default::default()
    };
    let jrep = joint_pm.run(g).expect("joint pipeline");
    let jplan = jrep.plan.as_ref().expect("plan");
    let joint =
        simulate_pipelined(&jrep.program, jplan, cfg, None).expect("joint plan verifies");

    // calibration: the search's predicted bytes are the simulated bytes
    let predicted = cost::evaluate(&jrep.program, jplan, cfg);
    assert_eq!(
        predicted.offchip_total(),
        joint.offchip_total(),
        "cost model out of calibration on the winning plan"
    );
    let opt_stats = jrep.opt.expect("opt stage ran");
    assert_eq!(
        opt_stats.best_offchip,
        joint.offchip_total(),
        "downstream replay diverged from the winning candidate"
    );
    Row { staged, joint, opt_stats }
}

fn main() {
    println!("\nE5 — whole-model joint optimization vs staged greedy (2 MiB scratchpad)\n");
    let cfg = two_mib();
    let mut records: Vec<Json> = Vec::new();
    let mut table = report::Table::new(&[
        "model",
        "staged off-chip",
        "joint off-chip",
        "reduction",
        "candidates",
        "decision",
    ]);
    for (name, g) in [
        ("resnet50", polymem::models::resnet50(1)),
        ("mobilenet", polymem::models::mobilenet_v1(1)),
    ] {
        let row = run_pair(g, &cfg);
        assert!(
            row.joint.offchip_total() < row.staged.offchip_total(),
            "{name}: joint off-chip {} not strictly below staged greedy {}",
            row.joint.offchip_total(),
            row.staged.offchip_total()
        );
        table.row(&[
            name.to_string(),
            report::mb(row.staged.offchip_total()),
            report::mb(row.joint.offchip_total()),
            format!(
                "{:.1}%",
                report::pct_reduction(row.staged.offchip_total(), row.joint.offchip_total())
            ),
            row.opt_stats.candidates.to_string(),
            row.opt_stats.decision.clone(),
        ]);
        records.push(Json::obj(vec![
            ("model", Json::Str(name.into())),
            ("accel", cfg.to_json()),
            ("staged", report::sim_to_json(&row.staged)),
            ("joint", report::sim_to_json(&row.joint)),
            ("opt_stats", row.opt_stats.to_json()),
            (
                "offchip_reduction_pct",
                Json::Num(report::pct_reduction(
                    row.staged.offchip_total(),
                    row.joint.offchip_total(),
                )),
            ),
        ]));
    }
    println!("{}", table.render());
    write_json_record("BENCH_opt.json", &Json::Arr(records));

    // ---- timing ----
    let mut suite = Suite::new("E5 timing");
    let g = polymem::models::mobilenet_v1(1);
    suite.add(Bench::new("opt+plan(mobilenet)").samples(2).run(|| {
        let pm = PassManager {
            opt: Some(OptStage::for_accel(cfg.clone())),
            alloc: Some(AllocStage::for_accel(cfg.clone())),
            verify: false,
            ..Default::default()
        };
        black_box(pm.run(g.clone()).unwrap())
    }));
    suite.finish();
}
