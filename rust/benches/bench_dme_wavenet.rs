//! Bench E1: regenerates the paper's first evaluation paragraph
//! (data-movement elimination on Parallel WaveNet) with timing.
//!
//! Run: `cargo bench --bench bench_dme_wavenet`

use polymem::accel::{simulate, AccelConfig};
use polymem::ir::Program;
use polymem::models::parallel_wavenet;
use polymem::models::wavenet::{parallel_wavenet_with, WaveNetConfig};
use polymem::passes::dme::run_dme;
use polymem::report;
use polymem::util::bench::{black_box, Bench, Suite};

fn main() {
    let cfg = AccelConfig::inferentia_like();

    // ---- the paper table ----
    let graph = parallel_wavenet();
    let before = simulate(&Program::lower(graph.clone()), &cfg, None);
    let mut prog = Program::lower(graph.clone());
    let stats = run_dme(&mut prog);
    let after = simulate(&prog, &cfg, None);
    println!("\nE1 — data-movement elimination on Parallel WaveNet\n");
    println!("{}", report::e1_table(&stats, &before, &after));
    assert_eq!(stats.pairs_eliminated, 123);
    assert_eq!(stats.pairs_before, 124);

    // ---- timing ----
    let mut suite = Suite::new("E1 timing");
    suite.add(
        Bench::new("lower(wavenet)")
            .samples(10)
            .run(|| black_box(Program::lower(graph.clone()))),
    );
    suite.add(
        Bench::new("dme(wavenet) full fixpoint")
            .samples(10)
            .run(|| {
                let mut p = Program::lower(graph.clone());
                black_box(run_dme(&mut p))
            }),
    );
    suite.add(
        Bench::new("simulate(wavenet, post-DME)")
            .samples(10)
            .run(|| black_box(simulate(&prog, &cfg, None))),
    );

    // ---- scaling series: DME time vs model size ----
    println!("\nDME scaling with layer count (flows x layers):");
    let mut t = report::Table::new(&["layers", "pairs", "eliminated", "time"]);
    for layers in [2usize, 5, 10, 20] {
        let wcfg = WaveNetConfig {
            layers_per_flow: layers,
            time: 6350 + 8200, // headroom for deeper stacks' receptive field
            ..Default::default()
        };
        let g = parallel_wavenet_with(wcfg);
        let t0 = std::time::Instant::now();
        let mut p = Program::lower(g);
        let s = run_dme(&mut p);
        let dt = t0.elapsed();
        t.row(&[
            format!("4 x {layers}"),
            s.pairs_before.to_string(),
            s.pairs_eliminated.to_string(),
            format!("{dt:?}"),
        ]);
    }
    println!("{}", t.render());
    suite.finish();
}
