//! Bench E2: regenerates the paper's second evaluation paragraph
//! (global vs local bank mapping on ResNet-50) with timing.
//!
//! Run: `cargo bench --bench bench_bank_mapping_resnet`

use polymem::accel::{simulate, AccelConfig, SimReport};
use polymem::passes::bank::BankStats;
use polymem::passes::manager::{BankMode, PassManager};
use polymem::report;
use polymem::util::bench::{black_box, Bench, Suite};

fn run_mode(mode: BankMode, batch: i64, cfg: &AccelConfig) -> (BankStats, SimReport) {
    let pm = PassManager { bank_mode: mode, ..Default::default() };
    let rep = pm.run(polymem::models::resnet50(batch)).expect("pipeline");
    let sim = simulate(&rep.program, cfg, None);
    (rep.bank.unwrap().stats, sim)
}

fn main() {
    let cfg = AccelConfig::inferentia_like();

    // ---- the paper table ----
    let (local_stats, local_sim) = run_mode(BankMode::Local, 1, &cfg);
    let (global_stats, global_sim) = run_mode(BankMode::Global, 1, &cfg);
    println!("\nE2 — global vs local bank mapping on ResNet-50\n");
    println!(
        "{}",
        report::e2_table(&local_stats, &global_stats, &local_sim, &global_sim)
    );
    let reduction =
        report::pct_reduction(local_sim.onchip_copy_total(), global_sim.onchip_copy_total());
    assert!(
        (60.0..90.0).contains(&reduction),
        "on-chip reduction {reduction:.1}% out of ballpark"
    );

    // ---- batch scaling series ----
    println!("batch scaling (who wins at every batch):\n");
    let mut t = report::Table::new(&[
        "batch",
        "local on-chip copies",
        "global on-chip copies",
        "reduction",
        "local lat",
        "global lat",
    ]);
    for batch in [1i64, 2, 4, 8] {
        let (_, l) = run_mode(BankMode::Local, batch, &cfg);
        let (_, g) = run_mode(BankMode::Global, batch, &cfg);
        t.row(&[
            batch.to_string(),
            report::mb(l.onchip_copy_total()),
            report::mb(g.onchip_copy_total()),
            format!(
                "{:.1}%",
                report::pct_reduction(l.onchip_copy_total(), g.onchip_copy_total())
            ),
            format!("{:.2} ms", l.seconds * 1e3),
            format!("{:.2} ms", g.seconds * 1e3),
        ]);
        assert!(g.onchip_copy_total() < l.onchip_copy_total());
    }
    println!("{}", t.render());

    // ---- timing ----
    let mut suite = Suite::new("E2 timing");
    suite.add(
        Bench::new("bank_local(resnet50)")
            .samples(10)
            .run(|| {
                let pm = PassManager { bank_mode: BankMode::Local, ..Default::default() };
                black_box(pm.run(polymem::models::resnet50(1)).unwrap())
            }),
    );
    suite.add(
        Bench::new("bank_global(resnet50)")
            .samples(10)
            .run(|| {
                let pm = PassManager { bank_mode: BankMode::Global, ..Default::default() };
                black_box(pm.run(polymem::models::resnet50(1)).unwrap())
            }),
    );
    suite.finish();
}
