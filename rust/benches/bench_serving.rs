//! Serving-layer benchmarks: batching policy overhead and end-to-end
//! throughput/latency. Uses the AOT artifact when present (run
//! `make artifacts` first), otherwise falls back to the echo backend
//! so the coordinator numbers are always measurable.
//!
//! Run: `cargo bench --bench bench_serving`

use polymem::coordinator::{EchoBackend, PjrtBackend, Server, ServerConfig};
use polymem::runtime::RuntimeClient;
use polymem::util::bench::Suite;
use polymem::util::rng::SplitMix64;
use std::path::Path;
use std::time::{Duration, Instant};

const CLASSES: usize = 10;

fn drive(srv: &Server, requests: usize, in_len: usize, seed: u64) -> Duration {
    let mut rng = SplitMix64::new(seed);
    let t0 = Instant::now();
    let handles: Vec<_> = (0..requests)
        .map(|_| {
            let img: Vec<f32> = (0..in_len).map(|_| rng.next_f64() as f32).collect();
            srv.submit(img).expect("submit")
        })
        .collect();
    for h in handles {
        h.wait().expect("inference");
    }
    t0.elapsed()
}

fn main() {
    let suite = Suite::new("serving coordinator");

    // ---- coordinator overhead with a zero-cost backend ----
    println!("\nbatching-policy overhead (echo backend, 4096 requests):");
    for max_batch in [1usize, 4, 16, 64] {
        let cfg = ServerConfig {
            max_batch,
            max_wait: Duration::from_micros(200),
            queue_cap: 1 << 16,
        };
        let srv = Server::start(EchoBackend::new(64, max_batch), cfg);
        let elapsed = drive(&srv, 4096, 64, 1);
        let snap = srv.metrics().snapshot();
        println!(
            "  max_batch {max_batch:>3}: {:>9.0} req/s, mean batch {:.2}, p99 {:?}",
            4096.0 / elapsed.as_secs_f64(),
            snap.mean_batch,
            snap.p99_latency
        );
        if max_batch == 64 {
            // what a metrics scrape endpoint would serve after the sweep
            println!("\nscrape rendering (max_batch 64):");
            for line in srv.metrics_text().lines() {
                println!("  {line}");
            }
        }
        srv.shutdown();
    }

    // ---- end-to-end on the real artifact ----
    let artifact = "artifacts/model.hlo.txt";
    if Path::new(artifact).exists() {
        println!("\nend-to-end PJRT serving (batch sweep, 512 requests each):");
        for batch in [1usize, 4, 8] {
            // batch-1 artifact for batch 1, batch-8 artifact otherwise;
            // the PjrtBackend pads partial batches.
            let path = if batch == 1 {
                "artifacts/model.b1.hlo.txt".to_string()
            } else {
                artifact.to_string()
            };
            let compiled_batch = if batch == 1 { 1 } else { 8 };
            if !Path::new(&path).exists() {
                continue;
            }
            let cfg = ServerConfig {
                max_batch: batch,
                max_wait: Duration::from_millis(2),
                queue_cap: 4096,
            };
            let srv = Server::start_with(
                move || {
                    let rt = RuntimeClient::cpu()?;
                    let model = rt.load_hlo_text(Path::new(&path))?;
                    Ok(PjrtBackend::new(model, compiled_batch, &[3, 32, 32], CLASSES))
                },
                cfg,
            )
            .expect("server");
            let elapsed = drive(&srv, 512, 3 * 32 * 32, 2);
            let snap = srv.metrics().snapshot();
            println!(
                "  client batch {batch}: {:>7.1} req/s, latency mean {:?} p99 {:?}, mean batch {:.2}",
                512.0 / elapsed.as_secs_f64(),
                snap.mean_latency,
                snap.p99_latency,
                snap.mean_batch
            );
            srv.shutdown();
        }
    } else {
        println!("\n(artifacts missing — run `make artifacts` for the PJRT end-to-end rows)");
    }

    suite.finish();
}
