//! E6 — the production serving path, end to end.
//!
//! 1. Coordinator overhead with a zero-cost echo backend (the fixed
//!    policy's bookkeeping floor).
//! 2. AOT plan cache on ResNet-50 under the cramped 2 MiB scratchpad:
//!    joint-optimized `(Program, MemoryPlan)` artifacts for the batch
//!    buckets {1, 2, 4, 8}, with predicted off-chip bytes/request and
//!    pipelined service time per bucket.
//! 3. Closed-loop and Poisson load simulations at equal offered load:
//!    cost-aware bucketized batching vs the fixed `max_batch = 8`
//!    baseline, reporting p50/p99 latency, sustained QPS and off-chip
//!    bytes/request per bucket set.
//! 4. A live `Server` over the `PlannedBackend` (real threads, real
//!    sleeps scaled down) to exercise the production wiring.
//!
//! Emits `$BENCH_JSON_DIR/BENCH_serving.json`.
//!
//! Run: `cargo bench --bench bench_serving`

use polymem::accel::AccelConfig;
use polymem::coordinator::{BucketCost, EchoBackend, Server, ServerConfig};
use polymem::serve::{
    run_load, Arrivals, LoadReport, LoadSimConfig, PlanCache, PlanCacheConfig, PlannedBackend,
    SloSpec,
};
use polymem::util::bench::{write_json_record, Suite};
use polymem::util::json::Json;
use polymem::util::rng::SplitMix64;
use std::time::{Duration, Instant};

/// The 2 MiB configuration (inferentia-like geometry, banks shrunk).
fn two_mib() -> AccelConfig {
    let mut cfg = AccelConfig::inferentia_like();
    cfg.bank_bytes /= 4; // 8 MiB -> 2 MiB
    cfg.name = "inferentia-like/4".into();
    cfg
}

fn drive(srv: &Server, requests: usize, in_len: usize, seed: u64) -> Duration {
    let mut rng = SplitMix64::new(seed);
    let t0 = Instant::now();
    let handles: Vec<_> = (0..requests)
        .map(|_| {
            let img: Vec<f32> = (0..in_len).map(|_| rng.next_f64() as f32).collect();
            srv.submit(img).expect("submit")
        })
        .collect();
    for h in handles {
        h.wait().expect("inference");
    }
    t0.elapsed()
}

fn print_load(r: &LoadReport) {
    println!(
        "  {:<28} buckets {:?}: p50 {:?} p99 {:?}, {:>9.0} qps, \
         {:>7.2} KiB/req, mean batch {:.2}, rejected {}",
        r.label,
        r.buckets,
        r.p50(),
        r.p99(),
        r.qps,
        r.bytes_per_request / 1024.0,
        r.mean_batch,
        r.rejected
    );
    if let Some(slo) = &r.slo {
        println!(
            "    {:<26} SLO {}us@{:.0}%: attainment {:.4}, error-budget burn {:.2}x",
            "", slo.objective_us, slo.target * 100.0, slo.attainment, slo.error_budget_burn
        );
    }
}

fn main() {
    let suite = Suite::new("serving coordinator");

    // ---- 1. coordinator overhead with a zero-cost backend ----
    println!("\nbatching-policy overhead (echo backend, 4096 requests):");
    for max_batch in [1usize, 8, 64] {
        let cfg = ServerConfig {
            max_batch,
            max_wait: Duration::from_micros(200),
            queue_cap: 1 << 16,
            ..Default::default()
        };
        let srv = Server::start(EchoBackend::new(64, max_batch), cfg);
        let elapsed = drive(&srv, 4096, 64, 1);
        let snap = srv.metrics().snapshot();
        println!(
            "  max_batch {max_batch:>3}: {:>9.0} req/s, mean batch {:.2}, p99 {:?}",
            4096.0 / elapsed.as_secs_f64(),
            snap.mean_batch,
            snap.p99_latency
        );
        srv.shutdown();
    }

    // ---- 2. AOT plan cache: ResNet-50 @ 2 MiB, joint optimizer ----
    let accel = two_mib();
    println!("\nplan cache: resnet50 @ {} (joint optimizer):", accel.name);
    let mut cache = PlanCache::new(
        "resnet50",
        PlanCacheConfig { accel: accel.clone(), joint: true, verify: false, max_entries: 0 },
    );
    let buckets: Vec<i64> = vec![1, 2, 4, 8];
    let arts = cache.compile_buckets(&buckets).expect("bucket compilation");
    for a in &arts {
        println!(
            "  b{:<2} off-chip {:>8.2} MiB ({:>8.2} MiB/req), service {:>7.3} ms, \
             compiled in {:>5.1} s [{}]",
            a.batch,
            a.cost.offchip_total() as f64 / (1 << 20) as f64,
            a.bytes_per_request() / (1 << 20) as f64,
            a.service_seconds * 1e3,
            a.compile_seconds,
            a.decision
        );
    }
    // memoization: a second lookup must be a cache hit, not a compile
    let again = cache.get_or_compile(8).expect("cached");
    assert_eq!(again.batch, 8);
    assert_eq!(cache.hits(), 1, "plan cache failed to memoize");
    assert_eq!(cache.misses(), buckets.len());

    let costs: Vec<BucketCost> = arts
        .iter()
        .map(|a| BucketCost {
            batch: a.batch as usize,
            offchip_bytes: a.cost.offchip_total(),
            service_seconds: a.service_seconds,
        })
        .collect();
    let fixed8 = vec![*costs.last().expect("bucket 8")];
    let svc8 = fixed8[0].service_seconds;
    let capacity8 = 8.0 / svc8; // full-batch saturation qps

    // ---- 3. load simulation: bucketized vs fixed at equal load ----
    println!(
        "\nclosed-loop / Poisson load simulation (bucket-8 capacity ≈ {capacity8:.0} qps):"
    );
    // score every run against a shared latency SLO: 4x the full-batch
    // service time at 99% attainment (loose enough for the low-load
    // runs, tight enough that saturation shows up as budget burn)
    let sim_cfg = LoadSimConfig {
        arrivals: Arrivals::Closed { clients: 12, requests: 4000 },
        max_wait: Duration::from_secs_f64(svc8 * 2.0),
        queue_cap: 64,
        slo: Some(SloSpec { latency: Duration::from_secs_f64(svc8 * 4.0), target: 0.99 }),
    };
    let loads: Vec<(&str, Arrivals)> = vec![
        (
            "poisson-low (0.25x cap)",
            Arrivals::Poisson { rate_qps: 0.25 * capacity8, requests: 4000, seed: 11 },
        ),
        (
            "poisson-high (0.8x cap)",
            Arrivals::Poisson { rate_qps: 0.8 * capacity8, requests: 4000, seed: 12 },
        ),
        ("closed-loop (12 clients)", Arrivals::Closed { clients: 12, requests: 4000 }),
    ];
    let mut rows: Vec<Json> = Vec::new();
    let mut low_load_win: Option<(f64, f64)> = None;
    for (label, arrivals) in &loads {
        let cfg = LoadSimConfig { arrivals: *arrivals, ..sim_cfg };
        let bucketized = run_load(&costs, &cfg, &format!("{label} / bucketized"));
        let fixed = run_load(&fixed8, &cfg, &format!("{label} / fixed8"));
        print_load(&bucketized);
        print_load(&fixed);
        println!(
            "    off-chip bytes/request: bucketized {:.0} vs fixed {:.0} ({:+.1}%)",
            bucketized.bytes_per_request,
            fixed.bytes_per_request,
            100.0 * (bucketized.bytes_per_request - fixed.bytes_per_request)
                / fixed.bytes_per_request
        );
        if label.starts_with("poisson-low") {
            low_load_win = Some((bucketized.bytes_per_request, fixed.bytes_per_request));
        }
        rows.push(bucketized.to_json());
        rows.push(fixed.to_json());
    }
    // the acceptance criterion: at equal offered load, cost-aware
    // bucketized batching moves strictly fewer predicted off-chip
    // bytes per request than the fixed max_batch=8 baseline
    let (bucket_bpr, fixed_bpr) = low_load_win.expect("low-load row ran");
    assert!(
        bucket_bpr < fixed_bpr,
        "bucketized batching did not beat the fixed baseline: {bucket_bpr} >= {fixed_bpr}"
    );

    // ---- 4. live server over the planned backend ----
    // real threads and real (scaled) service sleeps, exercising the
    // cost-aware flush path end to end
    println!("\nlive server over PlannedBackend (64 requests, time 1:1):");
    let backend = PlannedBackend::new(arts.clone()).expect("planned backend");
    let in_len = arts[0].in_len;
    let srv = Server::start(
        backend,
        ServerConfig {
            max_batch: 8,
            max_wait: Duration::from_secs_f64(svc8),
            queue_cap: 4096,
            ..Default::default()
        },
    );
    let elapsed = drive(&srv, 64, in_len, 3);
    let snap = srv.metrics().snapshot();
    println!(
        "  {:>6.1} req/s, mean batch {:.2}, p99 {:?}, predicted off-chip {:.2} MiB",
        64.0 / elapsed.as_secs_f64(),
        snap.mean_batch,
        snap.p99_latency,
        snap.predicted_offchip_bytes as f64 / (1 << 20) as f64
    );
    assert!(
        snap.predicted_offchip_bytes > 0,
        "cost-aware flush path never engaged"
    );
    // the drift auditor must read zero for the planned backend: its
    // replayed actuals are the same numbers the plan cache predicted
    for (b, d) in &snap.drift {
        assert_eq!(d.bytes_drift(), 0, "off-chip byte drift on bucket {b}");
        assert_eq!(d.seconds_drift(), 0.0, "service-seconds drift on bucket {b}");
    }
    println!(
        "  cost drift: 0 bytes / 0.0 s across {} audited bucket(s)",
        snap.drift.len()
    );
    srv.shutdown();

    // ---- machine-readable record ----
    let record = Json::obj(vec![
        ("model", Json::Str("resnet50".into())),
        ("accel", accel.to_json()),
        ("buckets", Json::Arr(arts.iter().map(|a| a.to_json()).collect())),
        (
            "cache",
            Json::obj(vec![
                ("hits", Json::Int(cache.hits() as i64)),
                ("misses", Json::Int(cache.misses() as i64)),
            ]),
        ),
        ("loads", Json::Arr(rows)),
        (
            "live_server",
            Json::obj(vec![
                ("requests", Json::Int(64)),
                ("mean_batch", Json::Num(snap.mean_batch)),
                ("p99_latency_us", Json::Int(snap.p99_latency.as_micros() as i64)),
                ("predicted_offchip_bytes", Json::Int(snap.predicted_offchip_bytes)),
            ]),
        ),
    ]);
    write_json_record("BENCH_serving.json", &record);

    suite.finish();
}
