//! Ablations over the design choices DESIGN.md calls out:
//!
//! * **A1** — DME fixpoint vs single sweep (is iteration needed?);
//! * **A2** — bank-count sweep (does the global-mapping win depend on
//!   the bank geometry?);
//! * **A3** — eviction-crossbar flexibility (`col_flex_limit`), the
//!   knob behind the residual copies of E2;
//! * **A4** — scratchpad-size sweep (when do copies fall off chip?);
//! * **A5** — joint decision search vs staged greedy: does solving the
//!   memory decisions together (the `opt` stage) beat the independent
//!   per-pass heuristics on a cramped chip?
//!
//! Run: `cargo bench --bench bench_ablations`

use polymem::accel::{simulate, simulate_pipelined, AccelConfig};
use polymem::ir::Program;
use polymem::models::{parallel_wavenet, resnet50};
use polymem::passes::bank::BankConfig;
use polymem::passes::dme::run_dme;
use polymem::passes::manager::{BankMode, PassManager};
use polymem::report;

/// A1: run DME with an iteration cap by chaining single passes.
fn dme_single_sweep(prog: &mut Program) -> polymem::passes::dme::DmeStats {
    // one fixpoint iteration = candidates scanned once; emulate by
    // running full DME on a clone and counting what ONE sweep achieves:
    // the public API iterates internally, so we measure convergence by
    // comparing iterations reported.
    run_dme(prog)
}

fn main() {
    let cfg = AccelConfig::inferentia_like();

    // ---- A1: DME iteration behaviour ----
    println!("\nA1 — DME fixpoint convergence (WaveNet, transformer):");
    let mut t1 = report::Table::new(&["model", "pairs", "eliminated", "iterations"]);
    for (name, g) in [
        ("wavenet", parallel_wavenet()),
        ("transformer", polymem::models::transformer_block(128, 256, 8, 1024)),
    ] {
        let mut p = Program::lower(g);
        let s = dme_single_sweep(&mut p);
        t1.row(&[
            name.to_string(),
            s.pairs_before.to_string(),
            s.pairs_eliminated.to_string(),
            s.iterations.to_string(),
        ]);
        assert!(
            s.iterations >= 2,
            "{name}: fixpoint converged in one sweep — iteration unnecessary?"
        );
    }
    println!("{}", t1.render());

    // ---- A2: bank-count sweep ----
    println!("A2 — bank-count sweep (ResNet-50, global vs local on-chip copy bytes):");
    let mut t2 = report::Table::new(&["banks", "local", "global", "reduction"]);
    for banks in [4usize, 8, 16, 32] {
        let mut results = vec![];
        for mode in [BankMode::Local, BankMode::Global] {
            let pm = PassManager {
                bank_mode: mode,
                bank_cfg: BankConfig { banks, ..Default::default() },
                ..Default::default()
            };
            let rep = pm.run(resnet50(1)).unwrap();
            let mut acfg = cfg.clone();
            acfg.banks = banks;
            results.push(simulate(&rep.program, &acfg, None).onchip_copy_total());
        }
        t2.row(&[
            banks.to_string(),
            report::mb(results[0]),
            report::mb(results[1]),
            format!("{:.1}%", report::pct_reduction(results[0], results[1])),
        ]);
        assert!(results[1] <= results[0]);
    }
    println!("{}", t2.render());

    // ---- A3: eviction-crossbar flexibility ----
    println!("A3 — eviction-crossbar flexibility (col_flex_limit, ResNet-50):");
    let local_base = {
        let pm = PassManager { bank_mode: BankMode::Local, ..Default::default() };
        let rep = pm.run(resnet50(1)).unwrap();
        simulate(&rep.program, &cfg, None).onchip_copy_total()
    };
    let mut t3 = report::Table::new(&["col_flex_limit", "remaps", "on-chip copies", "vs local"]);
    for limit in [128i64, 256, 512, 1024, 4096] {
        let pm = PassManager {
            bank_mode: BankMode::Global,
            bank_cfg: BankConfig { banks: 16, col_flex_limit: limit },
            ..Default::default()
        };
        let rep = pm.run(resnet50(1)).unwrap();
        let remaps = rep.bank.as_ref().unwrap().stats.copies_inserted;
        let bytes = simulate(&rep.program, &cfg, None).onchip_copy_total();
        t3.row(&[
            limit.to_string(),
            remaps.to_string(),
            report::mb(bytes),
            format!("-{:.1}%", report::pct_reduction(local_base, bytes)),
        ]);
    }
    println!("{}", t3.render());

    // ---- A4: scratchpad-size sweep ----
    println!("A4 — scratchpad size (ResNet-50 local mapping: where copies fall off chip):");
    let mut t4 = report::Table::new(&["scratchpad", "on-chip copies", "off-chip copies", "spills+reloads"]);
    for kib in [64i64, 128, 256, 512] {
        let mut acfg = cfg.clone();
        acfg.bank_bytes = kib * 1024;
        let pm = PassManager { bank_mode: BankMode::Local, ..Default::default() };
        let rep = pm.run(resnet50(1)).unwrap();
        let sim = simulate(&rep.program, &acfg, None);
        use polymem::accel::TrafficClass;
        t4.row(&[
            report::mb(acfg.scratchpad_bytes()),
            report::mb(sim.onchip_copy_total()),
            report::mb(
                sim.traffic.get(TrafficClass::OffchipCopy)
                    + sim.traffic.get(TrafficClass::OffchipRemap),
            ),
            report::mb(
                sim.traffic.get(TrafficClass::Spill) + sim.traffic.get(TrafficClass::Reload),
            ),
        ]);
    }
    println!("{}", t4.render());

    // ---- A5: joint decision search vs staged greedy ----
    println!("A5 — joint decision search vs staged greedy (ResNet-50, 2 MiB scratchpad):");
    use polymem::passes::manager::{AllocStage, OptStage, TileStage};
    let mut cramped = cfg.clone();
    cramped.bank_bytes /= 4;
    let staged_pm = PassManager {
        tile: Some(TileStage::for_accel(cramped.clone())),
        alloc: Some(AllocStage::for_accel(cramped.clone())),
        ..Default::default()
    };
    let srep = staged_pm.run(resnet50(1)).unwrap();
    let staged = simulate_pipelined(
        &srep.program,
        srep.plan.as_ref().unwrap(),
        &cramped,
        None,
    )
    .unwrap();
    let joint_pm = PassManager {
        opt: Some(OptStage::for_accel(cramped.clone())),
        alloc: Some(AllocStage::for_accel(cramped.clone())),
        ..Default::default()
    };
    let jrep = joint_pm.run(resnet50(1)).unwrap();
    let jstats = jrep.opt.as_ref().unwrap();
    let joint = simulate_pipelined(
        &jrep.program,
        jrep.plan.as_ref().unwrap(),
        &cramped,
        None,
    )
    .unwrap();
    let mut t5 = report::Table::new(&["pipeline", "off-chip", "pipelined latency", "note"]);
    t5.row(&[
        "staged greedy (tile+plan)".into(),
        report::mb(staged.offchip_total()),
        format!("{:.3} ms", staged.seconds * 1e3),
        "per-pass local proxies".into(),
    ]);
    t5.row(&[
        "joint search (opt)".into(),
        report::mb(joint.offchip_total()),
        format!("{:.3} ms", joint.seconds * 1e3),
        format!("{} candidates, {}", jstats.candidates, jstats.decision),
    ]);
    println!("{}", t5.render());
    assert!(
        joint.offchip_total() <= staged.offchip_total(),
        "joint search lost to the staged greedy it seeds from"
    );
}
