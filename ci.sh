#!/usr/bin/env bash
# One-shot verifier: build, tests (including the differential
# equivalence suite), and formatting.
#
#   ./ci.sh
#
# The differential fuzzer (`tests/diff_pipeline.rs`) runs with a fixed
# default seed and case count; override with FUZZ_SEED / FUZZ_CASES:
#
#   FUZZ_SEED=123 FUZZ_CASES=1 ./ci.sh     # replay one failing seed
#   FUZZ_CASES=1000 ./ci.sh                # deeper nightly sweep
#
# On a mismatch the suite panics with the exact failing seed and the
# first diverging (stage, tensor, element) — paste the printed
# FUZZ_SEED back into the command above to reproduce.
#
# `cargo fmt --check` runs only when a rustfmt component is installed
# (the offline build image may not carry one); build and tests are
# always mandatory.
set -euo pipefail
cd "$(dirname "$0")"

# fixed default seed for the differential suite (kept in sync with the
# in-code default in tests/diff_pipeline.rs)
: "${FUZZ_SEED:=4028782061}"
: "${FUZZ_CASES:=200}"
export FUZZ_SEED FUZZ_CASES

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q (differential suite runs inside: FUZZ_SEED=$FUZZ_SEED FUZZ_CASES=$FUZZ_CASES) =="
cargo test -q
echo "   (replay one differential case: FUZZ_SEED=<seed> FUZZ_CASES=1 cargo test --test diff_pipeline fuzzed)"

# Perf trajectory: the E3/E4/E5/E6 benches emit machine-readable
# records (target/BENCH_plan.json, target/BENCH_tile.json,
# target/BENCH_opt.json, target/BENCH_serving.json) every run, so the
# planned-vs-dynamic, tiled-vs-untiled, joint-vs-staged-greedy and
# bucketized-vs-fixed-batching numbers are tracked as artifacts rather
# than scrollback. bench_compile_time adds the compiler-telemetry
# record (per-model pass phases + joint-search profile); bench_serving
# also smoke-tests the AOT plan cache (ResNet-50 @ 2 MiB, buckets
# {1,2,4,8}) and asserts the bucketized policy's strict byte win at
# low load.
echo "== perf records: bench_alloc_plan + bench_tile + bench_opt + bench_compile_time + bench_serving + bench_multicore =="
mkdir -p target
BENCH_JSON_DIR=target cargo bench --bench bench_alloc_plan
BENCH_JSON_DIR=target cargo bench --bench bench_tile
BENCH_JSON_DIR=target cargo bench --bench bench_opt
BENCH_JSON_DIR=target cargo bench --bench bench_compile_time
BENCH_JSON_DIR=target cargo bench --bench bench_serving
BENCH_JSON_DIR=target cargo bench --bench bench_multicore
ls -l target/BENCH_plan.json target/BENCH_tile.json target/BENCH_opt.json \
      target/BENCH_compile_phases.json target/BENCH_serving.json \
      target/BENCH_multicore.json
test -s target/BENCH_serving.json
test -s target/BENCH_multicore.json

# Benchmark regression gate: the serving record is compared against the
# committed baseline in BENCH_baseline/ with a per-metric tolerance.
# Deterministic virtual-time metrics (qps, bytes/request, latency
# quantiles of the load sims) are gated; wall-clock-noisy paths
# (compile times, the live-server section) are skipped. On a fresh
# checkout with no baseline yet, --seed-missing adopts the current run
# (commit the generated file to tighten the gate from then on).
echo "== bench-regress: BENCH_serving.json vs BENCH_baseline/ =="
./target/release/polymem bench-regress \
    --baseline BENCH_baseline/BENCH_serving.json \
    --current target/BENCH_serving.json \
    --tol 0.15 \
    --skip compile_seconds,live_server \
    --seed-missing

# Multi-core sharding gate (E7): the record's QPS rows (single-core vs
# sharded at equal offered load, plus the sharded speedup ratio) and
# byte counters (off-chip, inter-core fabric) are deterministic
# virtual-time numbers and gated at the standard tolerance; wall-clock
# paths (stage compile times, the shard search) are skipped.
echo "== bench-regress: BENCH_multicore.json vs BENCH_baseline/ =="
./target/release/polymem bench-regress \
    --baseline BENCH_baseline/BENCH_multicore.json \
    --current target/BENCH_multicore.json \
    --tol 0.15 \
    --skip compile_seconds,search_seconds \
    --seed-missing

# Compiler-speed gate: the compile-phases record tracks joint-search
# throughput (candidates/second at beam widths 3/8/16, and the
# incremental-vs-full-serial speedup ratio — higher-is-better) plus the
# deterministic search outcomes (best_offchip, pipelined seconds —
# lower-is-better). Raw wall-clock paths (per-model mean_seconds, pass
# phase times, search/pool wall seconds) stay informational via --skip;
# throughput gets a generous 50% band since it is machine-sensitive,
# while the outcome metrics are bit-deterministic and effectively gated
# at equality.
echo "== bench-regress: BENCH_compile_phases.json vs BENCH_baseline/ =="
./target/release/polymem bench-regress \
    --baseline BENCH_baseline/BENCH_compile_phases.json \
    --current target/BENCH_compile_phases.json \
    --tol 0.5 \
    --skip mean_seconds,search_seconds,wall_seconds,phases,busy \
    --seed-missing

# Telemetry smoke: the acceptance scenario end to end — optimize full
# ResNet-50 under a cramped 2 MiB scratchpad, export the Chrome trace,
# print the per-layer attribution table and the compile-phase profile.
echo "== telemetry smoke: simulate --opt --trace-out =="
./target/release/polymem simulate --model resnet50 --scratchpad-kib 2048 \
    --opt --profile --top-layers 8 --trace-out target/trace_resnet50_opt.json
test -s target/trace_resnet50_opt.json

# Multi-core smoke: the shard search end to end — cut ResNet-18 across
# two cores, verify the bit-exact multi-engine replay (the command
# fails on any calibration drift), and export the per-core pipeline
# timeline as Chrome trace-event JSON.
echo "== multi-core smoke: simulate --cores 2 --trace-out =="
./target/release/polymem simulate --model resnet18 --scratchpad-kib 2048 \
    --cores 2 --opt --trace-out target/trace_resnet18_sharded.json
test -s target/trace_resnet18_sharded.json

# Serving-trace smoke: the observability path end to end — compile the
# ResNet-50 serving buckets at the same cramped 2 MiB scratchpad, run a
# traced load simulation over them, and export the request span chains
# as Chrome trace-event JSON.
echo "== serving-trace smoke: simulate --serve-trace-out =="
./target/release/polymem simulate --model resnet50 --scratchpad-kib 2048 \
    --serve-trace-out target/serve_trace_resnet50.json
test -s target/serve_trace_resnet50.json

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
else
    echo "== cargo fmt --check skipped (rustfmt not installed) =="
fi

# Lint gate over every target (lib, bin, tests, benches, examples).
# Promoted from advisory to REQUIRED: warnings are denied, and a lint
# failure fails CI. The availability check remains only because the
# offline build image cannot install a missing clippy component — when
# clippy is present, the gate is mandatory.
if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy --all-targets -- -D warnings (required gate) =="
    cargo clippy --all-targets -q -- -D warnings
else
    echo "== cargo clippy skipped (clippy not installed in this image) =="
fi

echo "ci.sh: all checks passed"
