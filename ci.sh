#!/usr/bin/env bash
# One-shot verifier: build, tests, and formatting.
#
#   ./ci.sh
#
# `cargo fmt --check` runs only when a rustfmt component is installed
# (the offline build image may not carry one); build and tests are
# always mandatory.
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
else
    echo "== cargo fmt --check skipped (rustfmt not installed) =="
fi

echo "ci.sh: all checks passed"
