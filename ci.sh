#!/usr/bin/env bash
# One-shot verifier: build, tests (including the differential
# equivalence suite), and formatting.
#
#   ./ci.sh
#
# The differential fuzzer (`tests/diff_pipeline.rs`) runs with a fixed
# default seed and case count; override with FUZZ_SEED / FUZZ_CASES:
#
#   FUZZ_SEED=123 FUZZ_CASES=1 ./ci.sh     # replay one failing seed
#   FUZZ_CASES=1000 ./ci.sh                # deeper nightly sweep
#
# On a mismatch the suite panics with the exact failing seed and the
# first diverging (stage, tensor, element) — paste the printed
# FUZZ_SEED back into the command above to reproduce.
#
# `cargo fmt --check` runs only when a rustfmt component is installed
# (the offline build image may not carry one); build and tests are
# always mandatory.
set -euo pipefail
cd "$(dirname "$0")"

# fixed default seed for the differential suite (kept in sync with the
# in-code default in tests/diff_pipeline.rs)
: "${FUZZ_SEED:=4028782061}"
: "${FUZZ_CASES:=200}"
export FUZZ_SEED FUZZ_CASES

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q (differential suite runs inside: FUZZ_SEED=$FUZZ_SEED FUZZ_CASES=$FUZZ_CASES) =="
cargo test -q
echo "   (replay one differential case: FUZZ_SEED=<seed> FUZZ_CASES=1 cargo test --test diff_pipeline fuzzed)"

# Perf trajectory: the E3/E4 benches emit machine-readable records
# (target/BENCH_plan.json, target/BENCH_tile.json) every run, so the
# planned-vs-dynamic and tiled-vs-untiled byte counts are tracked as
# artifacts rather than scrollback.
echo "== perf records: bench_alloc_plan + bench_tile =="
mkdir -p target
BENCH_JSON_DIR=target cargo bench --bench bench_alloc_plan
BENCH_JSON_DIR=target cargo bench --bench bench_tile
ls -l target/BENCH_plan.json target/BENCH_tile.json

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
else
    echo "== cargo fmt --check skipped (rustfmt not installed) =="
fi

# Lint pass over every target (lib, bin, tests, benches, examples),
# conditional like the fmt check (the offline image may not carry a
# clippy component). Warnings are reported but not fatal: the offline
# images pin no clippy version, and failing on a warning set that
# drifts across toolchains would make CI toolchain-dependent.
if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy --all-targets -q =="
    cargo clippy --all-targets -q
else
    echo "== cargo clippy skipped (clippy not installed) =="
fi

echo "ci.sh: all checks passed"
