"""AOT bridge: lower the L2 model to HLO **text** artifacts for the
Rust runtime.

HLO text — not `.serialize()` protos — is the interchange format: this
image's xla_extension 0.5.1 rejects jax≥0.5's 64-bit instruction ids,
while the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md). Lowered with `return_tuple=True`; the Rust
side unwraps the 1-tuple.

Usage:
    python -m compile.aot --out ../artifacts/model.hlo.txt
        writes the serving artifact (batch 8) plus a batch-1 variant
        next to it (model.b1.hlo.txt).
    python -m compile.aot --audit
        prints the L2 fusion audit (op histogram of the lowered HLO,
        VMEM/MXU structural metrics of the L1 kernel) without writing.
"""

import argparse
import collections
import os
import re
import sys

import jax
from jax._src.lib import xla_client as xc

from .kernels import banked_matmul as bmk
from .model import model_fn


def to_hlo_text(fn, spec) -> str:
    lowered = jax.jit(fn).lower(spec)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def audit(hlo_text: str):
    """Fusion/layout audit of a lowered module: op histogram and
    red-flag count of materialized transposes/copies (L2 §Perf)."""
    ops = collections.Counter()
    for line in hlo_text.splitlines():
        m = re.search(r"= \S+ (\w+)\(", line)
        if m:
            ops[m.group(1)] += 1
    return ops


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/model.hlo.txt")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--audit", action="store_true")
    args = ap.parse_args()

    fn, spec = model_fn(args.batch, seed=args.seed)
    text = to_hlo_text(fn, spec)

    if args.audit:
        ops = audit(text)
        print("== L2 HLO op histogram (batch %d) ==" % args.batch)
        for op, n in ops.most_common():
            print(f"  {op:<22} {n}")
        total = sum(ops.values())
        moves = ops["transpose"] + ops["copy"] + ops["reshape"]
        print(f"  data-movement ops: {moves}/{total}")
        print("== L1 kernel structural metrics ==")
        for m, k, n in [(1024, 27, 16), (256, 288, 64), (8, 64, 10)]:
            print(
                f"  matmul {m}x{k}x{n}: vmem/step = {bmk.vmem_bytes_per_step(m, k, n)}B,"
                f" mxu = {bmk.mxu_utilization(m, k, n):.2f}"
            )
        return

    out = os.path.abspath(args.out)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        f.write(text)
    print(f"wrote {len(text)} chars (batch {args.batch}) to {out}")

    # batch-1 variant for low-latency serving
    fn1, spec1 = model_fn(1, seed=args.seed)
    text1 = to_hlo_text(fn1, spec1)
    out1 = re.sub(r"\.hlo\.txt$", ".b1.hlo.txt", out)
    with open(out1, "w") as f:
        f.write(text1)
    print(f"wrote {len(text1)} chars (batch 1) to {out1}")


if __name__ == "__main__":
    sys.exit(main())
