"""L1 Pallas kernel: tiled bank remap (the `MemCopy` operator).

The inter-bank relocation the Rust passes materialize as `MemCopy`
nodes, expressed as a Pallas kernel: a tile-wise 2-D transpose whose
grid walks destination tiles — each grid step reads one source tile
from the "old" banking and deposits it transposed into the "new" one.
Used by the serving example to realize layout changes on the real
(PJRT) execution path, and as a second, structurally different kernel
for the correctness suite.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _remap_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].T


def _clamp_tile(dim, want):
    t = min(dim, want)
    while dim % t:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("bt",))
def bank_transpose(x, bt=128):
    """[A, B] -> [B, A] tile-wise (destination-indexed grid)."""
    a, b = x.shape
    ta = _clamp_tile(a, bt)
    tb = _clamp_tile(b, bt)
    grid = (b // tb, a // ta)  # destination tiles: [B, A] in (tb, ta) blocks
    return pl.pallas_call(
        _remap_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ta, tb), lambda i, j: (j, i))],
        out_specs=pl.BlockSpec((tb, ta), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, a), x.dtype),
        interpret=True,
    )(x)
