"""L1 Pallas kernel: bank-tiled matmul — the compute hot-spot.

Hardware adaptation of the paper's bank mapping to Pallas/TPU idioms
(DESIGN.md §Hardware-Adaptation):

* the grid axis over N is the **bank axis**: each grid step `j` owns
  one `bn`-wide slab of output columns — the Pallas realization of
  "the result … spread across several banks, guided by the different
  output channels";
* the K dimension stays whole inside a block — operand rows enter the
  MXU spread across banks by contraction dim, which is the Row-aligned
  placement the bank-mapping pass establishes (`Placement::row` on the
  channel dim);
* block shapes default to MXU-friendly 128×128 tiles and are clamped
  to the problem size; `python -m compile.aot --audit` prints the VMEM
  footprint per grid step so the schedule can be checked against the
  512 KiB bank budget.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU performance is *estimated* from the block
geometry (EXPERIMENTS.md §Perf), while numerics are validated here.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref):
    # One (bm × bn) output tile per grid step; K is resident whole.
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _clamp_tile(dim, want):
    """Largest divisor of `dim` not exceeding `want` (block shapes must
    tile the array exactly; shapes here are compile-time constants)."""
    t = min(dim, want)
    while dim % t:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def banked_matmul(x, w, bm=128, bn=128):
    """[M, K] @ [K, N] -> [M, N] via a bank-tiled Pallas kernel."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = _clamp_tile(m, bm)
    bn = _clamp_tile(n, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w)


def vmem_bytes_per_step(m, k, n, bm=128, bn=128, elem=4):
    """Static VMEM footprint of one grid step (operands + result tile) —
    the §Perf structural metric checked against the bank budget."""
    bm = _clamp_tile(m, bm)
    bn = _clamp_tile(n, bn)
    return elem * (bm * k + k * bn + bm * bn)


def mxu_utilization(m, k, n, bm=128, bn=128, mxu=128):
    """Fraction of MXU lanes a (bm, bn, k) tile keeps busy — 1.0 when
    both tile sides fill the 128-wide systolic array."""
    bm = _clamp_tile(m, bm)
    bn = _clamp_tile(n, bn)
    return min(bm, mxu) * min(bn, mxu) / float(mxu * mxu)
