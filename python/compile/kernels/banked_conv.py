"""L1 Pallas kernel: conv2d as bank-tiled im2col matmul.

The systolic-array formulation the paper's chip uses: unfold the NCHW
input into patch rows (im2col — a *layout* producer that the L2 graph
keeps adjacent to the matmul so XLA fuses it instead of materializing
an intermediate, mirroring what DME achieves in the Rust compiler),
then contract patches against reshaped OIHW weights on the MXU with
the bank-tiled matmul kernel.
"""

import jax.numpy as jnp

from . import banked_matmul as bm
from . import ref


def banked_conv2d(x, w, stride=1, padding=0, bn=128):
    """NCHW × OIHW → NCHW convolution through the Pallas matmul.

    x: [N, C, H, W]; w: [O, C, KH, KW].
    """
    n, c, h, wd = x.shape
    o, c2, kh, kw = w.shape
    assert c == c2, f"channel mismatch {c} vs {c2}"
    patches, oh, ow = ref.im2col_nchw(x, kh, kw, stride, padding)
    # [N, OH*OW, C*KH*KW] @ [C*KH*KW, O] — O is the banked axis
    wmat = w.reshape(o, c * kh * kw).T
    out = jnp.stack(
        [bm.banked_matmul(patches[i], wmat, bn=bn) for i in range(n)], axis=0
    )  # [N, OH*OW, O]
    return jnp.transpose(out, (0, 2, 1)).reshape(n, o, oh, ow)
