"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference here; pytest asserts
allclose between kernel and oracle across hypothesis-generated shapes.
The oracles are deliberately written with stock jax.numpy / lax ops —
no Pallas, no custom tiling — so a disagreement always indicts the
kernel.
"""

import jax.numpy as jnp
from jax import lax


def matmul_ref(x, w):
    """[M, K] @ [K, N] -> [M, N] in f32 accumulation."""
    return jnp.matmul(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def conv2d_nchw_ref(x, w, stride=1, padding=0):
    """NCHW x OIHW conv, symmetric padding, f32 accumulation."""
    out = lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=((padding, padding), (padding, padding)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out.astype(x.dtype)


def bank_transpose_ref(x):
    """Layout remap oracle: 2-D transpose."""
    return jnp.swapaxes(x, 0, 1)


def im2col_nchw(x, kh, kw, stride=1, padding=0):
    """Unfold NCHW input into [N, OH*OW, C*KH*KW] patches (row-major
    over (kh, kw) then c, matching the OIHW weight reshape below)."""
    n, c, h, w = x.shape
    if padding:
        x = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (w + 2 * padding - kw) // stride + 1
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            patch = lax.slice(
                x,
                (0, 0, dy, dx),
                (n, c, dy + (oh - 1) * stride + 1, dx + (ow - 1) * stride + 1),
                (1, 1, stride, stride),
            )  # [N, C, OH, OW]
            cols.append(patch)
    # list of [N, C, OH, OW] -> [N, OH*OW, C*KH*KW] with (c, dy, dx) order
    stacked = jnp.stack(cols, axis=2)  # [N, C, KH*KW, OH, OW]
    out = jnp.transpose(stacked, (0, 3, 4, 1, 2)).reshape(n, oh * ow, c * kh * kw)
    return out, oh, ow
