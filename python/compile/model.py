"""L2: the JAX model — a small CNN classifier whose convolutions run
through the L1 bank-tiled Pallas kernels.

This is the model the Rust serving layer executes end to end: weights
are generated once from a fixed seed and baked into the lowered HLO as
constants, so the artifact is self-contained — the request path feeds
images only.

Architecture (CIFAR-sized, NCHW):
    conv3x3(3→16) + relu
    conv3x3(16→32, stride 2) + relu
    conv3x3(32→64, stride 2) + relu
    global average pool
    dense 64→10
"""

import jax
import jax.numpy as jnp

from .kernels.banked_conv import banked_conv2d
from .kernels.banked_matmul import banked_matmul
from .kernels import ref

LAYERS = (
    # (name, cin, cout, stride)
    ("conv1", 3, 16, 1),
    ("conv2", 16, 32, 2),
    ("conv3", 32, 64, 2),
)
CLASSES = 10


def init_params(seed=0):
    """He-initialized weights, deterministic in `seed`."""
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, cin, cout, _stride in LAYERS:
        key, k1 = jax.random.split(key)
        fan_in = cin * 9
        params[name] = jax.random.normal(k1, (cout, cin, 3, 3), jnp.float32) * (
            (2.0 / fan_in) ** 0.5
        )
    key, k1 = jax.random.split(key)
    params["fc"] = jax.random.normal(k1, (64, CLASSES), jnp.float32) * (
        (2.0 / 64) ** 0.5
    )
    return params


def forward(params, x, use_pallas=True):
    """Classifier forward: [N, 3, 32, 32] -> [N, 10] logits."""
    conv = banked_conv2d if use_pallas else ref.conv2d_nchw_ref
    for name, _cin, _cout, stride in LAYERS:
        x = conv(x, params[name], stride=stride, padding=1)
        x = jax.nn.relu(x)
    x = jnp.mean(x, axis=(2, 3))  # global average pool -> [N, 64]
    if use_pallas:
        return banked_matmul(x, params["fc"])
    return ref.matmul_ref(x, params["fc"])


def model_fn(batch, seed=0, use_pallas=True):
    """Closure over baked weights: images -> logits."""
    params = init_params(seed)

    def fn(x):
        return (forward(params, x, use_pallas=use_pallas),)

    return fn, jax.ShapeDtypeStruct((batch, 3, 32, 32), jnp.float32)
