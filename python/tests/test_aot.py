"""AOT artifact checks: the emitted HLO text must re-parse, expose the
expected entry signature, and contain the model's compute ops."""

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile.aot import audit, to_hlo_text
from compile.model import model_fn


def lower(batch=2):
    fn, spec = model_fn(batch)
    return to_hlo_text(fn, spec)


def test_hlo_text_emitted_and_reparses():
    text = lower()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # round-trip through the HLO text parser (what the Rust side does)
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None


def test_entry_signature():
    text = lower(batch=4)
    # single parameter of shape f32[4,3,32,32]
    assert "f32[4,3,32,32]" in text
    # tuple output with 10 classes
    assert "f32[4,10]" in text


def test_audit_histogram():
    text = lower()
    ops = audit(text)
    assert ops.get("dot", 0) >= 1, "matmul must survive lowering"
    assert sum(ops.values()) > 5
    # interpret-mode pallas must lower to plain HLO (no custom-call)
    assert ops.get("custom-call", 0) == 0, "Mosaic custom-call leaked into artifact"


def test_artifact_numerics_match_eager():
    """The lowered computation must agree with eager execution — this is
    exactly the parity the Rust runtime relies on."""
    fn, spec = model_fn(2)
    x = jax.random.normal(jax.random.PRNGKey(3), spec.shape, spec.dtype)
    (eager,) = fn(x)
    compiled = jax.jit(fn).lower(spec).compile()
    (aot_out,) = compiled(x)
    np.testing.assert_allclose(
        np.asarray(eager), np.asarray(aot_out), rtol=1e-5, atol=1e-5
    )


def test_batch1_variant_differs_only_in_batch():
    t1 = lower(batch=1)
    t8 = lower(batch=8)
    assert "f32[1,3,32,32]" in t1
    assert "f32[8,3,32,32]" in t8
