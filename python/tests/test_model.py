"""L2 model checks: shape, determinism, and Pallas-vs-reference parity."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import forward, init_params, model_fn


def test_output_shape():
    params = init_params()
    x = jnp.zeros((2, 3, 32, 32), jnp.float32)
    out = forward(params, x)
    assert out.shape == (2, 10)


def test_deterministic_in_seed():
    p1 = init_params(seed=42)
    p2 = init_params(seed=42)
    p3 = init_params(seed=43)
    for k in p1:
        np.testing.assert_array_equal(np.asarray(p1[k]), np.asarray(p2[k]))
    assert any(
        not np.array_equal(np.asarray(p1[k]), np.asarray(p3[k])) for k in p1
    )


def test_pallas_path_matches_reference_path():
    params = init_params()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 32, 32), jnp.float32)
    got = forward(params, x, use_pallas=True)
    want = forward(params, x, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_model_fn_closure():
    fn, spec = model_fn(batch=4)
    assert spec.shape == (4, 3, 32, 32)
    x = jnp.ones(spec.shape, spec.dtype)
    (out,) = fn(x)
    assert out.shape == (4, 10)
    # same seed → same logits
    fn2, _ = model_fn(batch=4)
    (out2,) = fn2(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_logits_not_degenerate():
    fn, spec = model_fn(batch=3)
    x = jax.random.normal(jax.random.PRNGKey(2), spec.shape, spec.dtype)
    (out,) = fn(x)
    # different inputs produce different logits and finite values
    assert np.isfinite(np.asarray(out)).all()
    assert not np.allclose(np.asarray(out)[0], np.asarray(out)[1])
