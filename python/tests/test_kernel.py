"""Kernel vs oracle — the CORE numeric correctness signal.

Hypothesis sweeps shapes and dtypes; every Pallas kernel must agree
with its pure-jnp oracle to float tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.banked_conv import banked_conv2d
from compile.kernels.banked_matmul import (
    banked_matmul,
    mxu_utilization,
    vmem_bytes_per_step,
)
from compile.kernels.layout import bank_transpose

DTYPES = [jnp.float32, jnp.bfloat16]


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-5, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 64),
    n=st.integers(1, 96),
    dti=st.integers(0, len(DTYPES) - 1),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, dti, seed):
    dtype = DTYPES[dti]
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = rand(k1, (m, k), dtype)
    w = rand(k2, (k, n), dtype)
    got = banked_matmul(x, w)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol(dtype)
    )


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([32, 128, 256]),
    k=st.sampled_from([16, 64]),
    n=st.sampled_from([128, 192, 256]),
    bm=st.sampled_from([32, 64, 128]),
    bn=st.sampled_from([32, 64, 128]),
)
def test_matmul_tile_shapes_dont_change_numerics(m, k, n, bm, bn):
    key = jax.random.PRNGKey(m * 7 + n)
    k1, k2 = jax.random.split(key)
    x = rand(k1, (m, k), jnp.float32)
    w = rand(k2, (k, n), jnp.float32)
    base = banked_matmul(x, w)
    tiled = banked_matmul(x, w, bm=bm, bn=bn)
    np.testing.assert_allclose(np.asarray(base), np.asarray(tiled), rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 2),
    c=st.sampled_from([1, 3, 8]),
    hw=st.sampled_from([6, 9, 16]),
    o=st.sampled_from([4, 16]),
    k=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    dti=st.integers(0, len(DTYPES) - 1),
)
def test_conv2d_matches_lax(n, c, hw, o, k, stride, dti):
    dtype = DTYPES[dti]
    pad = (k - 1) // 2
    key = jax.random.PRNGKey(n * 1000 + c * 100 + hw)
    k1, k2 = jax.random.split(key)
    x = rand(k1, (n, c, hw, hw), dtype)
    w = rand(k2, (o, c, k, k), dtype)
    got = banked_conv2d(x, w, stride=stride, padding=pad)
    want = ref.conv2d_nchw_ref(x, w, stride=stride, padding=pad)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol(dtype)
    )


@settings(max_examples=25, deadline=None)
@given(
    a=st.integers(1, 200),
    b=st.integers(1, 200),
    bt=st.sampled_from([16, 64, 128]),
    dti=st.integers(0, len(DTYPES) - 1),
)
def test_bank_transpose_matches_ref(a, b, bt, dti):
    dtype = DTYPES[dti]
    x = rand(jax.random.PRNGKey(a * 211 + b), (a, b), dtype)
    got = bank_transpose(x, bt=bt)
    want = ref.bank_transpose_ref(x)
    assert got.shape == (b, a)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_im2col_shapes_and_content():
    x = jnp.arange(2 * 3 * 5 * 5, dtype=jnp.float32).reshape(2, 3, 5, 5)
    patches, oh, ow = ref.im2col_nchw(x, 3, 3, stride=1, padding=1)
    assert (oh, ow) == (5, 5)
    assert patches.shape == (2, 25, 27)
    # center patch of the interior equals the raw 3x3 neighbourhood
    got = patches[0, 2 * 5 + 2]  # pixel (2,2)
    want = x[0, :, 1:4, 1:4].reshape(-1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_vmem_budget_structural():
    # serving-model shapes stay within one 256 KiB bank per operand set
    for m, k, n in [(1024, 27, 16), (256, 144, 32), (64, 288, 64), (8, 64, 10)]:
        assert vmem_bytes_per_step(m, k, n) <= 512 * 1024, (m, k, n)
    # utilization reaches 1.0 for MXU-sized tiles
    assert mxu_utilization(256, 64, 256) == 1.0
    assert mxu_utilization(8, 64, 10) < 0.1


@pytest.mark.parametrize("m,k,n", [(1, 1, 1), (1, 64, 128), (128, 1, 1), (97, 13, 51)])
def test_matmul_edge_shapes(m, k, n):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = rand(k1, (m, k), jnp.float32)
    w = rand(k2, (k, n), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(banked_matmul(x, w)),
        np.asarray(ref.matmul_ref(x, w)),
        rtol=1e-5,
        atol=1e-5,
    )
