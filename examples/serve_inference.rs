//! End-to-end validation: all three layers compose.
//!
//! Loads the AOT-compiled JAX/Pallas CNN artifact (L2+L1, built by
//! `make artifacts`), serves batched synthetic requests through the
//! Rust coordinator (L3) on the PJRT CPU runtime, and reports
//! latency/throughput — the serving-paper driver required by the
//! project brief. Python is not involved at any point of this binary.
//!
//! ```sh
//! make artifacts
//! cargo run --release --example serve_inference
//! ```

use polymem::coordinator::{PjrtBackend, Server, ServerConfig};
use polymem::runtime::RuntimeClient;
use polymem::util::rng::SplitMix64;
use std::path::Path;
use std::time::{Duration, Instant};

const BATCH: usize = 8;
const CLASSES: usize = 10;
const REQUESTS: usize = 512;

fn main() {
    let artifact = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts/model.hlo.txt".to_string());
    if !Path::new(&artifact).exists() {
        eprintln!("artifact {artifact} not found — run `make artifacts` first");
        std::process::exit(1);
    }

    let cfg = ServerConfig {
        max_batch: BATCH,
        max_wait: Duration::from_millis(2),
        queue_cap: 4096,
        ..Default::default()
    };
    let artifact2 = artifact.clone();
    let srv = Server::start_with(
        move || {
            let rt = RuntimeClient::cpu()?;
            println!(
                "PJRT platform: {} ({} devices)",
                rt.platform(),
                rt.device_count()
            );
            let model = rt.load_hlo_text(Path::new(&artifact2))?;
            Ok(PjrtBackend::new(model, BATCH, &[3, 32, 32], CLASSES))
        },
        cfg,
    )
    .expect("starting server");

    // synthetic CIFAR-shaped request stream
    let mut rng = SplitMix64::new(2026);
    let t0 = Instant::now();
    let handles: Vec<_> = (0..REQUESTS)
        .map(|_| {
            let img: Vec<f32> = (0..3 * 32 * 32)
                .map(|_| (rng.next_f64() as f32) * 2.0 - 1.0)
                .collect();
            srv.submit(img).expect("submit")
        })
        .collect();

    let mut class_histogram = [0usize; CLASSES];
    for h in handles {
        let logits = h.wait().expect("inference");
        assert_eq!(logits.len(), CLASSES);
        assert!(logits.iter().all(|v| v.is_finite()), "non-finite logits");
        let argmax = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        class_histogram[argmax] += 1;
    }
    let elapsed = t0.elapsed();
    let snap = srv.metrics().snapshot();

    println!("\nserved {REQUESTS} requests in {elapsed:?}");
    println!(
        "throughput: {:.1} req/s  |  latency mean {:?} p50 {:?} p99 {:?}",
        REQUESTS as f64 / elapsed.as_secs_f64(),
        snap.mean_latency,
        snap.p50_latency,
        snap.p99_latency
    );
    println!(
        "batches: {} (mean batch {:.2}), errors: {}",
        snap.batches, snap.mean_batch, snap.errors
    );
    println!("predicted-class histogram: {class_histogram:?}");
    assert_eq!(snap.requests as usize, REQUESTS);
    assert_eq!(snap.errors, 0);
    assert!(snap.mean_batch > 1.0, "batching never engaged");
    srv.shutdown();
    println!("e2e OK — L1 (pallas) + L2 (jax) + L3 (rust) compose");
}
