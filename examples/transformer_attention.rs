//! Extra workload: DME on a transformer encoder block.
//!
//! Multi-head attention's reshape/transpose/slice plumbing is the same
//! memory-bound glue the paper's §2.1 pass targets in WaveNet —
//! showing the optimization generalizes beyond the paper's evaluation.
//!
//! ```sh
//! cargo run --release --example transformer_attention
//! ```

use polymem::accel::{simulate, AccelConfig};
use polymem::ir::Program;
use polymem::models::transformer_block;
use polymem::passes::dme::run_dme;
use polymem::report;

fn main() {
    let cfg = AccelConfig::inferentia_like();
    let mut table = report::Table::new(&[
        "seq x d_model (heads)",
        "pairs eliminated",
        "intermediates freed",
        "on-chip movement",
        "latency",
    ]);
    for (seq, d, heads) in [(64i64, 128i64, 4i64), (128, 256, 8), (256, 256, 8)] {
        let g = transformer_block(seq, d, heads, 4 * d);
        let before = simulate(&Program::lower(g.clone()), &cfg, None);
        let mut prog = Program::lower(g);
        let stats = run_dme(&mut prog);
        let after = simulate(&prog, &cfg, None);
        table.row(&[
            format!("{seq} x {d} ({heads})"),
            format!("{}/{}", stats.pairs_eliminated, stats.pairs_before),
            report::mb(stats.bytes_eliminated),
            format!(
                "{} -> {}",
                report::mb(before.onchip_movement_total()),
                report::mb(after.onchip_movement_total())
            ),
            format!("{:.2} -> {:.2} ms", before.seconds * 1e3, after.seconds * 1e3),
        ]);
        assert!(stats.pairs_eliminated * 10 >= stats.pairs_before * 8, "80%+ expected");
    }
    println!("DME on transformer encoder blocks\n\n{}", table.render());
}
