//! Paper experiment E1: data-movement elimination on Parallel WaveNet.
//!
//! Reproduces the §3 result: "eliminate 123 out of 124 load-store
//! pairs … eliminated 145 MB (out of 146 MB) of tensors that were used
//! for intermediate storage."
//!
//! ```sh
//! cargo run --release --example wavenet_dme
//! ```

use polymem::accel::{simulate, AccelConfig};
use polymem::ir::Program;
use polymem::models::parallel_wavenet;
use polymem::passes::dme::run_dme;
use polymem::passes::liveness::Liveness;
use polymem::report;

fn main() {
    let cfg = AccelConfig::inferentia_like();
    let graph = parallel_wavenet();
    println!(
        "Parallel WaveNet graph: {} nodes, {} weights",
        graph.nodes().len(),
        graph
            .tensors()
            .filter(|t| t.kind == polymem::ir::TensorKind::Weight)
            .count()
    );

    let before_prog = Program::lower(graph.clone());
    let before_sim = simulate(&before_prog, &cfg, None);
    let before_live = Liveness::analyze(&before_prog);
    let peak_before = before_live.peak_live_bytes(&before_prog);

    let mut prog = Program::lower(graph);
    let t0 = std::time::Instant::now();
    let stats = run_dme(&mut prog);
    let dme_time = t0.elapsed();
    let after_sim = simulate(&prog, &cfg, None);
    let after_live = Liveness::analyze(&prog);
    let peak_after = after_live.peak_live_bytes(&prog);

    println!("\nE1 — data-movement elimination on Parallel WaveNet\n");
    println!("{}", report::e1_table(&stats, &before_sim, &after_sim));
    println!(
        "peak live intermediates: {} -> {}",
        report::mb(peak_before),
        report::mb(peak_after)
    );
    println!(
        "DME ran in {dme_time:?} over {} fixed-point iterations",
        stats.iterations
    );

    // the paper's headline must hold
    assert_eq!(stats.pairs_before, 124);
    assert_eq!(stats.pairs_eliminated, 123);
}
