//! Quickstart: build a small model with the public API, run the full
//! optimization pipeline, and read the accelerator traffic report.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use polymem::accel::{simulate, AccelConfig};
use polymem::ir::{Graph, GraphBuilder};
use polymem::passes::manager::{BankMode, PassManager};

fn build() -> Graph {
    // A conv block whose input arrives in the wrong layout (NHWC),
    // giving both passes something to do.
    let mut b = GraphBuilder::new();
    let x_nhwc = b.input("image_nhwc", &[1, 32, 32, 16]);
    let x = b.transpose("to_nchw", x_nhwc, &[0, 3, 1, 2]); // memory-bound glue
    let w1 = b.weight("w1", &[32, 16, 3, 3]);
    let c1 = b.conv2d("conv1", x, w1, 1, 1);
    let bn1 = b.batchnorm("bn1", c1);
    let r1 = b.relu("relu1", bn1);
    let w2 = b.weight("w2", &[32, 32, 3, 3]);
    let c2 = b.conv2d("conv2", r1, w2, 1, 1);
    let sum = b.add("residual", c2, c1);
    let out = b.relu("out", sum);
    b.mark_output(out);
    b.finish()
}

fn main() {
    let graph = build();
    println!(
        "built graph: {} nodes, {} tensors",
        graph.nodes().len(),
        graph.tensors().count()
    );

    // Optimize: DME (§2.1) + global bank mapping (§2.2).
    let pm = PassManager::default();
    let report = pm.run(graph).expect("pipeline failed");
    let dme = report.dme.as_ref().unwrap();
    println!(
        "DME eliminated {}/{} load-store pairs ({} bytes of intermediates)",
        dme.pairs_eliminated, dme.pairs_before, dme.bytes_eliminated
    );
    let bank = report.bank.as_ref().unwrap();
    println!(
        "global bank mapping: {} remap copies inserted, {} edges already aligned",
        bank.stats.copies_inserted, bank.stats.edges_matched
    );

    // Measure on the simulated accelerator.
    let accel = AccelConfig::inferentia_like();
    let sim = simulate(&report.program, &accel, None);
    println!("\ntraffic on {}:", accel.name);
    println!("{}", sim.traffic.to_json().to_string_pretty());

    // Compare against the unoptimized schedule.
    let pm_off = PassManager {
        enable_dme: false,
        bank_mode: BankMode::Local,
        ..Default::default()
    };
    let base = pm_off.run(build()).unwrap();
    let base_sim = simulate(&base.program, &accel, None);
    println!(
        "\nunoptimized: on-chip movement {:>9} B, latency {:.3} ms",
        base_sim.onchip_movement_total(),
        base_sim.seconds * 1e3
    );
    println!(
        "optimized:   on-chip movement {:>9} B, latency {:.3} ms",
        sim.onchip_movement_total(),
        sim.seconds * 1e3
    );
    assert!(sim.onchip_movement_total() < base_sim.onchip_movement_total());
}
