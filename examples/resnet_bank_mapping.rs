//! Paper experiment E2: global vs local memory-bank mapping on
//! ResNet-50, plus a bank-count sweep.
//!
//! Reproduces the §3 result: "global mapping eliminate[s] 76% of the
//! on-chip data copies and 37% of the copies off chip."
//!
//! ```sh
//! cargo run --release --example resnet_bank_mapping
//! ```

use polymem::accel::{simulate, AccelConfig, SimReport};
use polymem::passes::bank::BankStats;
use polymem::passes::manager::{BankMode, PassManager};
use polymem::report;

fn run_mode(mode: BankMode, batch: i64, cfg: &AccelConfig) -> (BankStats, SimReport) {
    let pm = PassManager { bank_mode: mode, ..Default::default() };
    let rep = pm.run(polymem::models::resnet50(batch)).expect("pipeline");
    let sim = simulate(&rep.program, cfg, None);
    (rep.bank.unwrap().stats, sim)
}

fn main() {
    let cfg = AccelConfig::inferentia_like();
    let (local_stats, local_sim) = run_mode(BankMode::Local, 1, &cfg);
    let (global_stats, global_sim) = run_mode(BankMode::Global, 1, &cfg);

    println!("E2 — global vs local bank mapping on ResNet-50\n");
    println!(
        "{}",
        report::e2_table(&local_stats, &global_stats, &local_sim, &global_sim)
    );

    // who wins must match the paper
    assert!(global_sim.onchip_copy_total() < local_sim.onchip_copy_total());
    let reduction = report::pct_reduction(
        local_sim.onchip_copy_total(),
        global_sim.onchip_copy_total(),
    );
    assert!(
        (60.0..90.0).contains(&reduction),
        "on-chip reduction {reduction:.1}% out of the paper's ballpark"
    );

    // ablation: how the win scales with the eviction-crossbar limit
    println!("\nablation: eviction-crossbar flexibility (col_flex_limit)\n");
    let mut t = report::Table::new(&[
        "col_flex_limit",
        "global remaps",
        "on-chip copy bytes",
        "reduction vs local",
    ]);
    for limit in [128i64, 256, 512, 1024, 4096] {
        let pm = PassManager {
            bank_mode: BankMode::Global,
            bank_cfg: polymem::passes::bank::BankConfig { banks: 16, col_flex_limit: limit },
            ..Default::default()
        };
        let rep = pm.run(polymem::models::resnet50(1)).unwrap();
        let sim = simulate(&rep.program, &cfg, None);
        t.row(&[
            limit.to_string(),
            rep.bank.as_ref().unwrap().stats.copies_inserted.to_string(),
            report::mb(sim.onchip_copy_total()),
            format!(
                "{:.1}%",
                report::pct_reduction(local_sim.onchip_copy_total(), sim.onchip_copy_total())
            ),
        ]);
    }
    println!("{}", t.render());
}
